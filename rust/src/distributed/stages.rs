//! Stage runner: manifest + per-worker stage execution, behind the same
//! backend split as the single-process runtime.
//!
//! Every worker owns a `StageRunner`: workers are real independent
//! "machines" that share nothing but the fabric. Under `backend-xla` the
//! runner compiles the per-stage HLO artifacts on its own PJRT client;
//! otherwise it executes the same stage algebra in pure Rust on the
//! cache-blocked [`tensor`](crate::runtime::tensor) kernels -- the exact
//! math of `python/compile/dist_stages.py` (`s1_fwd`, `expert_fwd`,
//! `head_loss_bwd`, `expert_bwd`, `s1_bwd`), so the distributed engine,
//! its collectives, and the Gating Dropout skip path all run on a stock
//! toolchain with no artifacts on disk.
//!
//! `DistManifest::load("synthetic")` yields a deterministic generated
//! model (the `dist_stages.py` default config with seeded init) for
//! exactly that artifact-free mode.

use crate::runtime::tensor::{mm, mm_at, mm_bt, relu, softmax_rows, softmax_vjp_rows, ThreadPool};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{bail, ensure};

/// The default `dist_stages.py` DistConfig, used by the synthetic model.
const SYN_D_IN: usize = 32;
const SYN_D_MODEL: usize = 64;
const SYN_D_FF: usize = 256;
const SYN_N_CLASSES: usize = 16;
const SYN_TOKENS_PER_RANK: usize = 64;
const SYN_RANKS: usize = 4;
const SYN_SEED: u64 = 7;

/// Parsed `artifacts/dist/manifest.json`, or the synthetic equivalent.
#[derive(Debug, Clone)]
pub struct DistManifest {
    pub dir: std::path::PathBuf,
    pub d_in: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub tokens_per_rank: usize,
    pub ranks: usize,
    pub files: std::collections::BTreeMap<String, String>,
    pub init_files: std::collections::BTreeMap<String, (Vec<usize>, String)>,
    /// When set, `load_init` generates parameters deterministically from
    /// this seed instead of reading `.bin` files.
    pub synthetic_seed: Option<u64>,
}

impl DistManifest {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<DistManifest> {
        let dir = dir.as_ref().to_path_buf();
        if dir == std::path::Path::new("synthetic") {
            return Ok(DistManifest::synthetic());
        }
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("{}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| crate::err!("dist manifest: {e}"))?;
        let c = j.get("config").context("dist manifest: config")?;
        let g = |k: &str| c.get(k).and_then(Json::as_usize).context(k.to_string());
        let mut files = std::collections::BTreeMap::new();
        for (name, art) in j.get("artifacts").and_then(Json::as_obj).context("artifacts")? {
            files.insert(
                name.clone(),
                art.get("file").and_then(Json::as_str).context("file")?.to_string(),
            );
        }
        let mut init_files = std::collections::BTreeMap::new();
        for e in j.get("params_init").and_then(Json::as_arr).context("params_init")? {
            let name = e.get("name").and_then(Json::as_str).context("name")?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let file = e.get("file").and_then(Json::as_str).context("file")?;
            init_files.insert(name.to_string(), (shape, file.to_string()));
        }
        Ok(DistManifest {
            d_in: g("d_in")?,
            d_model: g("d_model")?,
            d_ff: g("d_ff")?,
            n_classes: g("n_classes")?,
            tokens_per_rank: g("tokens_per_rank")?,
            ranks: g("ranks")?,
            files,
            init_files,
            synthetic_seed: None,
            dir,
        })
    }

    /// The artifact-free model: `dist_stages.py` default dims, seeded
    /// deterministic init, pure-Rust stage execution.
    pub fn synthetic() -> DistManifest {
        let (d, f) = (SYN_D_MODEL, SYN_D_FF);
        let mut init_files = std::collections::BTreeMap::new();
        let mut add = |name: String, shape: Vec<usize>| {
            init_files.insert(name, (shape, String::new()));
        };
        add("w_in".into(), vec![SYN_D_IN, d]);
        add("b_in".into(), vec![d]);
        add("wr".into(), vec![d, SYN_RANKS]);
        add("w_out".into(), vec![d, SYN_N_CLASSES]);
        for e in 0..SYN_RANKS {
            add(format!("expert{e}_w1"), vec![d, f]);
            add(format!("expert{e}_w2"), vec![f, d]);
        }
        DistManifest {
            dir: std::path::PathBuf::from("synthetic"),
            d_in: SYN_D_IN,
            d_model: d,
            d_ff: f,
            n_classes: SYN_N_CLASSES,
            tokens_per_rank: SYN_TOKENS_PER_RANK,
            ranks: SYN_RANKS,
            files: std::collections::BTreeMap::new(),
            init_files,
            synthetic_seed: Some(SYN_SEED),
        }
    }

    pub fn load_init(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, file) =
            self.init_files.get(name).with_context(|| format!("no init param '{name}'"))?;
        if let Some(seed) = self.synthetic_seed {
            return Ok(synth_init(name, shape, seed, self.d_in, self.d_model, self.d_ff));
        }
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path).with_context(|| path.display().to_string())?;
        let expect: usize = shape.iter().product::<usize>() * 4;
        ensure!(bytes.len() == expect, "{name}: {} != {expect}", bytes.len());
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Deterministic synthetic init, `dist_stages.py` scales: normal times
/// 1/sqrt(fan_in), biases zero. Streams are keyed by parameter name so
/// every rank generates identical dense parameters.
fn synth_init(name: &str, shape: &[usize], seed: u64, d_in: usize, d: usize, f: usize) -> Vec<f32> {
    let n: usize = shape.iter().product();
    if name == "b_in" {
        return vec![0.0; n];
    }
    let scale = if name == "w_in" {
        1.0 / (d_in as f32).sqrt()
    } else if name.ends_with("_w2") {
        1.0 / (f as f32).sqrt()
    } else {
        1.0 / (d as f32).sqrt() // wr, w_out, expert w1
    };
    // FNV-1a over the name keys the stream.
    let mut key: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        key = (key ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    let mut rng = Rng::new(seed ^ 0xD157).fork(key);
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// One stage input: a shaped f32 matrix, an f32 vector, or an i32 vector.
/// (The XLA runner turns these into PJRT literals; the reference runner
/// consumes the slices directly.)
pub enum StageArg<'a> {
    F2(&'a [f32], usize, usize),
    F1(&'a [f32]),
    I1(&'a [i32]),
}

pub fn lit2(data: &[f32], r: usize, c: usize) -> Result<StageArg<'_>> {
    ensure!(data.len() == r * c, "lit2: {} elements for {r}x{c}", data.len());
    Ok(StageArg::F2(data, r, c))
}

pub fn lit1(data: &[f32]) -> StageArg<'_> {
    StageArg::F1(data)
}

pub fn lit1_i32(data: &[i32]) -> StageArg<'_> {
    StageArg::I1(data)
}

/// One worker's stage executor.
pub struct StageRunner {
    pub manifest: DistManifest,
    /// Optional worker pool for the pure-Rust stage math: the per-rank
    /// thread budget the distributed engine resolves (see
    /// `distributed::engine`). The stage matmuls go through the shared
    /// `tensor::mm`/`mm_at`/`mm_bt` dispatch seam, so an attached pool
    /// changes wall time, never bits. The XLA stage path ignores it.
    pool: Option<ThreadPool>,
    #[cfg(feature = "backend-xla")]
    xla: XlaStages,
}

impl StageRunner {
    #[cfg(feature = "backend-xla")]
    pub fn new(manifest: DistManifest) -> Result<StageRunner> {
        let xla = XlaStages::new(&manifest)?;
        Ok(StageRunner { manifest, pool: None, xla })
    }

    #[cfg(not(feature = "backend-xla"))]
    pub fn new(manifest: DistManifest) -> Result<StageRunner> {
        Ok(StageRunner { manifest, pool: None })
    }

    /// Attach a persistent worker pool: subsequent pure-Rust stage
    /// executions fan their matmuls out over the pool's workers
    /// (bit-identical to the sequential path at any count). The caller
    /// builds the pool so env knobs (`GD_SEQ_CUTOFF`) are resolved --
    /// and their parse errors surfaced -- once, up front, not inside
    /// every rank thread.
    pub fn set_thread_pool(&mut self, pool: ThreadPool) {
        self.pool = Some(pool);
    }

    /// Worker threads in use for the pure-Rust stage math (1 = inline).
    pub fn thread_count(&self) -> usize {
        self.pool.as_ref().map_or(1, ThreadPool::threads)
    }

    /// Execute stage `name`; returns the flattened tuple outputs as f32
    /// vecs (i32 outputs are not used by any stage). A synthetic manifest
    /// has no HLO files, so it always runs the pure-Rust stages -- even
    /// on `backend-xla` builds.
    pub fn run(&self, name: &str, args: &[StageArg]) -> Result<Vec<Vec<f32>>> {
        if self.manifest.synthetic_seed.is_some() {
            return ref_stage(name, args, self.pool.as_ref());
        }
        #[cfg(feature = "backend-xla")]
        {
            self.xla.run(name, args)
        }
        #[cfg(not(feature = "backend-xla"))]
        {
            ref_stage(name, args, self.pool.as_ref())
        }
    }
}

// ---------------------------------------------------------------------------
// XLA stage execution (compiled HLO artifacts, one PJRT client per worker)

#[cfg(feature = "backend-xla")]
struct XlaStages {
    client: xla::PjRtClient,
    exes: std::collections::BTreeMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "backend-xla")]
impl XlaStages {
    fn new(manifest: &DistManifest) -> Result<XlaStages> {
        let client = xla::PjRtClient::cpu()?;
        let mut exes = std::collections::BTreeMap::new();
        for (name, file) in &manifest.files {
            let path = manifest.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path")?)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.insert(name.clone(), client.compile(&comp).context(name.clone())?);
        }
        Ok(XlaStages { client, exes })
    }

    fn run(&self, name: &str, args: &[StageArg]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exes.get(name).with_context(|| format!("no stage '{name}'"))?;
        let lits = args
            .iter()
            .map(|a| {
                Ok(match a {
                    StageArg::F2(d, r, c) => {
                        xla::Literal::vec1(d).reshape(&[*r as i64, *c as i64])?
                    }
                    StageArg::F1(d) => xla::Literal::vec1(d),
                    StageArg::I1(d) => xla::Literal::vec1(d),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // leak-free path: execute() leaks its input device buffers (see
        // runtime::engine::exec_leakfree); upload via owned buffers.
        let mut bufs = Vec::with_capacity(lits.len());
        for lit in &lits {
            bufs.push(self.client.buffer_from_host_literal(None, lit)?);
        }
        let res = exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        let parts = res[0][0].to_literal_sync()?.to_tuple()?;
        parts.into_iter().map(|p| Ok(p.to_vec::<f32>()?)).collect()
    }
}

// ---------------------------------------------------------------------------
// Reference stage execution (pure Rust, the dist_stages.py math verbatim)

fn f2<'a>(args: &'a [StageArg], i: usize, stage: &str) -> Result<(&'a [f32], usize, usize)> {
    match args.get(i) {
        Some(StageArg::F2(d, r, c)) => Ok((*d, *r, *c)),
        _ => bail!("{stage}: arg {i} must be an f32 matrix"),
    }
}

fn f1<'a>(args: &'a [StageArg], i: usize, stage: &str) -> Result<&'a [f32]> {
    match args.get(i) {
        Some(StageArg::F1(d)) => Ok(*d),
        _ => bail!("{stage}: arg {i} must be an f32 vector"),
    }
}

fn i1<'a>(args: &'a [StageArg], i: usize, stage: &str) -> Result<&'a [i32]> {
    match args.get(i) {
        Some(StageArg::I1(d)) => Ok(*d),
        _ => bail!("{stage}: arg {i} must be an i32 vector"),
    }
}

/// Pure-Rust execution of one stage (see `dist_stages.py` for the exact
/// formulas this mirrors). Every matmul goes through the shared
/// `tensor::mm`/`mm_at`/`mm_bt` dispatch seam, so handing a pool threads
/// the stage without forking its math; the pooled kernels are
/// bit-identical to the sequential ones, so `pool` changes wall time,
/// never the returned bits.
pub fn ref_stage(
    name: &str,
    args: &[StageArg],
    pool: Option<&ThreadPool>,
) -> Result<Vec<Vec<f32>>> {
    match name {
        // h = relu(x@w_in + b_in); probs = softmax(h@wr)
        "s1_fwd" => {
            let (w_in, din, d) = f2(args, 0, name)?;
            let b_in = f1(args, 1, name)?;
            let (wr, _, r) = f2(args, 2, name)?;
            let (x, t, _) = f2(args, 3, name)?;
            let mut h = vec![0f32; t * d];
            mm(pool, &mut h, x, w_in, t, din, d);
            for row in h.chunks_exact_mut(d) {
                for (hv, &bv) in row.iter_mut().zip(b_in) {
                    *hv += bv;
                }
            }
            relu(&mut h);
            let mut probs = vec![0f32; t * r];
            mm(pool, &mut probs, &h, wr, t, d, r);
            softmax_rows(&mut probs, t, r);
            Ok(vec![h, probs])
        }
        // ye = relu(xe@w1) @ w2
        "expert_fwd" => {
            let (w1, d, f) = f2(args, 0, name)?;
            let (w2, _, _) = f2(args, 1, name)?;
            let (xe, t, _) = f2(args, 2, name)?;
            let mut hid = vec![0f32; t * f];
            mm(pool, &mut hid, xe, w1, t, d, f);
            relu(&mut hid);
            let mut ye = vec![0f32; t * d];
            mm(pool, &mut ye, &hid, w2, t, f, d);
            Ok(vec![ye])
        }
        // logits = y@w_out; loss = -mean(logp[label]); (loss, dy, dw_out)
        "head_loss_bwd" => {
            let (w_out, d, k) = f2(args, 0, name)?;
            let (y, t, _) = f2(args, 1, name)?;
            let labels = i1(args, 2, name)?;
            ensure!(labels.len() == t, "{name}: {} labels for {t} tokens", labels.len());
            let mut p = vec![0f32; t * k];
            mm(pool, &mut p, y, w_out, t, d, k);
            softmax_rows(&mut p, t, k);
            let mut loss = 0f32;
            let inv_t = 1.0 / t as f32;
            for (i, &lab) in labels.iter().enumerate() {
                ensure!((lab as usize) < k, "{name}: label {lab} out of range");
                loss -= p[i * k + lab as usize].max(1e-30).ln();
                // dlogits = (softmax - onehot) / t, folded in place
                for v in p[i * k..(i + 1) * k].iter_mut() {
                    *v *= inv_t;
                }
                p[i * k + lab as usize] -= inv_t;
            }
            let mut dy = vec![0f32; t * d];
            mm_bt(pool, &mut dy, &p, w_out, t, k, d);
            let mut dw_out = vec![0f32; d * k];
            mm_at(pool, &mut dw_out, y, &p, t, d, k);
            Ok(vec![vec![loss * inv_t], dy, dw_out])
        }
        // VJP of expert_fwd (recompute-forward): (dxe, dw1, dw2)
        "expert_bwd" => {
            let (w1, d, f) = f2(args, 0, name)?;
            let (w2, _, _) = f2(args, 1, name)?;
            let (xe, t, _) = f2(args, 2, name)?;
            let (dye, _, _) = f2(args, 3, name)?;
            let mut pre = vec![0f32; t * f];
            mm(pool, &mut pre, xe, w1, t, d, f);
            let mut hid = pre.clone();
            relu(&mut hid);
            let mut dw2 = vec![0f32; f * d];
            mm_at(pool, &mut dw2, &hid, dye, t, f, d);
            let mut dpre = vec![0f32; t * f];
            mm_bt(pool, &mut dpre, dye, w2, t, d, f);
            for (dp, &pr) in dpre.iter_mut().zip(&pre) {
                if pr <= 0.0 {
                    *dp = 0.0;
                }
            }
            let mut dw1 = vec![0f32; d * f];
            mm_at(pool, &mut dw1, xe, &dpre, t, d, f);
            let mut dxe = vec![0f32; t * d];
            mm_bt(pool, &mut dxe, &dpre, w1, t, f, d);
            Ok(vec![dxe, dw1, dw2])
        }
        // Row-chunked slice of expert_bwd for the pipelined schedule: the
        // token-axis ops of a contiguous slot range. Returns (dxe_c,
        // hid_c, dpre_c); the caller concatenates hid_c/dpre_c across
        // chunks and runs "expert_bwd_dw" ONCE so dw1/dw2 keep the
        // monolithic accumulation order (mm_at sums over the token axis,
        // so per-chunk dw matmuls would reorder f32 adds). The per-row
        // ops here are bit-identical to the same rows inside a monolithic
        // expert_bwd because mm/mm_bt accumulate per (row, col) over k
        // only -- row subsets never change any row's bits.
        "expert_bwd_chunk" => {
            let (w1, d, f) = f2(args, 0, name)?;
            let (w2, _, _) = f2(args, 1, name)?;
            let (xe, t, _) = f2(args, 2, name)?;
            let (dye, _, _) = f2(args, 3, name)?;
            let mut pre = vec![0f32; t * f];
            mm(pool, &mut pre, xe, w1, t, d, f);
            let mut hid = pre.clone();
            relu(&mut hid);
            let mut dpre = vec![0f32; t * f];
            mm_bt(pool, &mut dpre, dye, w2, t, d, f);
            for (dp, &pr) in dpre.iter_mut().zip(&pre) {
                if pr <= 0.0 {
                    *dp = 0.0;
                }
            }
            let mut dxe = vec![0f32; t * d];
            mm_bt(pool, &mut dxe, &dpre, w1, t, f, d);
            Ok(vec![dxe, hid, dpre])
        }
        // Weight-gradient tail of the chunked expert backward: one pass
        // over the FULL (concatenated) buffers, so the token-axis sums in
        // dw1/dw2 run in exactly the monolithic expert_bwd order.
        "expert_bwd_dw" => {
            let (xe, t, d) = f2(args, 0, name)?;
            let (hid, _, f) = f2(args, 1, name)?;
            let (dpre, _, _) = f2(args, 2, name)?;
            let (dye, _, _) = f2(args, 3, name)?;
            let mut dw2 = vec![0f32; f * d];
            mm_at(pool, &mut dw2, hid, dye, t, f, d);
            let mut dw1 = vec![0f32; d * f];
            mm_at(pool, &mut dw1, xe, dpre, t, d, f);
            Ok(vec![dw1, dw2])
        }
        // VJP of s1_fwd given cotangents for h and probs: (dw_in, db_in, dwr)
        "s1_bwd" => {
            let (w_in, din, d) = f2(args, 0, name)?;
            let b_in = f1(args, 1, name)?;
            let (wr, _, r) = f2(args, 2, name)?;
            let (x, t, _) = f2(args, 3, name)?;
            let (dh, _, _) = f2(args, 4, name)?;
            let (dprobs, _, _) = f2(args, 5, name)?;
            let mut pre = vec![0f32; t * d];
            mm(pool, &mut pre, x, w_in, t, din, d);
            for row in pre.chunks_exact_mut(d) {
                for (pv, &bv) in row.iter_mut().zip(b_in) {
                    *pv += bv;
                }
            }
            let mut h = pre.clone();
            relu(&mut h);
            let mut probs = vec![0f32; t * r];
            mm(pool, &mut probs, &h, wr, t, d, r);
            softmax_rows(&mut probs, t, r);
            let mut dlogits = vec![0f32; t * r];
            softmax_vjp_rows(&mut dlogits, &probs, dprobs, t, r);
            let mut dwr = vec![0f32; d * r];
            mm_at(pool, &mut dwr, &h, &dlogits, t, d, r);
            let mut dh_total = vec![0f32; t * d];
            mm_bt(pool, &mut dh_total, &dlogits, wr, t, r, d);
            for (dv, &hv) in dh_total.iter_mut().zip(dh) {
                *dv += hv;
            }
            for (dv, &pv) in dh_total.iter_mut().zip(&pre) {
                if pv <= 0.0 {
                    *dv = 0.0;
                }
            }
            let mut dw_in = vec![0f32; din * d];
            mm_at(pool, &mut dw_in, x, &dh_total, t, din, d);
            let mut db_in = vec![0f32; d];
            for row in dh_total.chunks_exact(d) {
                for (bv, &dv) in db_in.iter_mut().zip(row) {
                    *bv += dv;
                }
            }
            Ok(vec![dw_in, db_in, dwr])
        }
        other => bail!("unknown stage '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_is_deterministic_and_shaped() {
        let a = DistManifest::load("synthetic").unwrap();
        let b = DistManifest::synthetic();
        assert_eq!(a.ranks, SYN_RANKS);
        assert_eq!(a.d_model, SYN_D_MODEL);
        let wa = a.load_init("w_in").unwrap();
        let wb = b.load_init("w_in").unwrap();
        assert_eq!(wa, wb, "synthetic init must be reproducible");
        assert_eq!(wa.len(), SYN_D_IN * SYN_D_MODEL);
        assert!(b.load_init("b_in").unwrap().iter().all(|&v| v == 0.0));
        // per-expert weights differ between experts
        assert_ne!(a.load_init("expert0_w1").unwrap(), a.load_init("expert1_w1").unwrap());
        assert!(a.load_init("nope").is_err());
    }

    /// Finite-difference check of the hand-written stage VJPs: the
    /// reference dist stages must implement the dist_stages.py gradients,
    /// not merely plausible ones.
    #[test]
    fn ref_stage_gradients_match_finite_differences() {
        let (t, din, d, r, f, k) = (6usize, 5usize, 8usize, 4usize, 7usize, 3usize);
        let mut rng = Rng::new(42);
        let rand_vec = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
        };
        let w_in = rand_vec(&mut rng, din * d);
        let b_in = rand_vec(&mut rng, d);
        let wr = rand_vec(&mut rng, d * r);
        let x = rand_vec(&mut rng, t * din);
        let w_out = rand_vec(&mut rng, d * k);
        let labels: Vec<i32> = (0..t).map(|i| (i % k) as i32).collect();

        // scalar objective: head loss on y = h (s1 output), so the chain
        // s1_fwd -> head_loss_bwd -> s1_bwd is exercised end to end.
        let loss_of = |w_in_: &[f32], b_in_: &[f32], wr_: &[f32]| -> f32 {
            let out = ref_stage(
                "s1_fwd",
                &[
                    lit2(w_in_, din, d).unwrap(),
                    lit1(b_in_),
                    lit2(wr_, d, r).unwrap(),
                    lit2(&x, t, din).unwrap(),
                ],
                None,
            )
            .unwrap();
            let h = &out[0];
            let probs = &out[1];
            let head = ref_stage(
                "head_loss_bwd",
                &[lit2(&w_out, d, k).unwrap(), lit2(h, t, d).unwrap(), lit1_i32(&labels)],
                None,
            )
            .unwrap();
            // add a probs-dependent term so dwr is exercised: sum(probs^2)
            head[0][0] + probs.iter().map(|&p| p * p).sum::<f32>()
        };

        // analytic grads via the stages
        let out = ref_stage(
            "s1_fwd",
            &[
                lit2(&w_in, din, d).unwrap(),
                lit1(&b_in),
                lit2(&wr, d, r).unwrap(),
                lit2(&x, t, din).unwrap(),
            ],
            None,
        )
        .unwrap();
        let (h, probs) = (&out[0], &out[1]);
        let head = ref_stage(
            "head_loss_bwd",
            &[lit2(&w_out, d, k).unwrap(), lit2(h, t, d).unwrap(), lit1_i32(&labels)],
            None,
        )
        .unwrap();
        let dh = &head[1];
        let dprobs: Vec<f32> = probs.iter().map(|&p| 2.0 * p).collect();
        let grads = ref_stage(
            "s1_bwd",
            &[
                lit2(&w_in, din, d).unwrap(),
                lit1(&b_in),
                lit2(&wr, d, r).unwrap(),
                lit2(&x, t, din).unwrap(),
                lit2(dh, t, d).unwrap(),
                lit2(&dprobs, t, r).unwrap(),
            ],
            None,
        )
        .unwrap();

        let check = |name: &str, analytic: &[f32], param: &[f32], which: usize| {
            let mut checked = 0usize;
            for probe in [0usize, param.len() / 2, param.len() - 1] {
                let fd_at = |eps: f32| -> f32 {
                    let mut plus = param.to_vec();
                    plus[probe] += eps;
                    let mut minus = param.to_vec();
                    minus[probe] -= eps;
                    let (lp, lm) = match which {
                        0 => (loss_of(&plus, &b_in, &wr), loss_of(&minus, &b_in, &wr)),
                        1 => (loss_of(&w_in, &plus, &wr), loss_of(&w_in, &minus, &wr)),
                        _ => (loss_of(&w_in, &b_in, &plus), loss_of(&w_in, &b_in, &minus)),
                    };
                    (lp - lm) / (2.0 * eps)
                };
                let (fd1, fd2) = (fd_at(1e-2), fd_at(5e-3));
                if (fd1 - fd2).abs() > 0.1 * fd1.abs().max(fd2.abs()).max(1e-2) {
                    continue; // a ReLU kink inside the probe interval
                }
                let diff = (fd2 - analytic[probe]).abs();
                let scale = fd2.abs().max(analytic[probe].abs()).max(1e-2);
                assert!(diff / scale < 0.15, "{name}[{probe}]: fd {fd2} vs {}", analytic[probe]);
                checked += 1;
            }
            assert!(checked > 0, "{name}: every probe hit a kink (suspicious)");
        };
        check("dw_in", &grads[0], &w_in, 0);
        check("db_in", &grads[1], &b_in, 1);
        check("dwr", &grads[2], &wr, 2);
    }

    #[test]
    fn expert_bwd_matches_finite_differences() {
        let (t, d, f) = (5usize, 6usize, 9usize);
        let mut rng = Rng::new(3);
        let rand_vec = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
        };
        let w1 = rand_vec(&mut rng, d * f);
        let w2 = rand_vec(&mut rng, f * d);
        let xe = rand_vec(&mut rng, t * d);
        // objective: 0.5 * ||ye||^2  =>  dye = ye
        let fwd = |w1_: &[f32], xe_: &[f32]| -> f32 {
            let out = ref_stage(
                "expert_fwd",
                &[
                    lit2(w1_, d, f).unwrap(),
                    lit2(&w2, f, d).unwrap(),
                    lit2(xe_, t, d).unwrap(),
                ],
                None,
            )
            .unwrap();
            0.5 * out[0].iter().map(|&v| v * v).sum::<f32>()
        };
        let out = ref_stage(
            "expert_fwd",
            &[lit2(&w1, d, f).unwrap(), lit2(&w2, f, d).unwrap(), lit2(&xe, t, d).unwrap()],
            None,
        )
        .unwrap();
        let ye = &out[0];
        let grads = ref_stage(
            "expert_bwd",
            &[
                lit2(&w1, d, f).unwrap(),
                lit2(&w2, f, d).unwrap(),
                lit2(&xe, t, d).unwrap(),
                lit2(ye, t, d).unwrap(),
            ],
            None,
        )
        .unwrap();
        let mut checked = 0usize;
        for (name, analytic, param, is_w1) in
            [("dxe", &grads[0], &xe, false), ("dw1", &grads[1], &w1, true)]
        {
            for probe in [0usize, param.len() - 1] {
                let fd_at = |eps: f32| -> f32 {
                    let mut plus = param.clone();
                    plus[probe] += eps;
                    let mut minus = param.clone();
                    minus[probe] -= eps;
                    let (lp, lm) = if is_w1 {
                        (fwd(&plus, &xe), fwd(&minus, &xe))
                    } else {
                        (fwd(&w1, &plus), fwd(&w1, &minus))
                    };
                    (lp - lm) / (2.0 * eps)
                };
                let (fd1, fd2) = (fd_at(1e-2), fd_at(5e-3));
                if (fd1 - fd2).abs() > 0.1 * fd1.abs().max(fd2.abs()).max(1e-2) {
                    continue; // ReLU kink inside the probe interval
                }
                let diff = (fd2 - analytic[probe]).abs();
                let scale = fd2.abs().max(analytic[probe].abs()).max(1e-2);
                assert!(diff / scale < 0.15, "{name}[{probe}]: fd {fd2} vs {}", analytic[probe]);
                checked += 1;
            }
        }
        assert!(checked > 0, "every probe hit a kink (suspicious)");
    }

    /// The chunked expert backward (per-chunk "expert_bwd_chunk" + one
    /// trailing "expert_bwd_dw" over the concatenated buffers) must
    /// reconstruct the monolithic "expert_bwd" outputs BITWISE at any
    /// chunk count -- this is the contract that lets the distributed
    /// engine pipeline the dye/dxe legs without changing a single bit.
    #[test]
    fn chunked_expert_bwd_reconstructs_monolithic_bitwise() {
        let (t, d, f) = (10usize, 6usize, 9usize);
        let mut rng = Rng::new(17);
        let rand_vec = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
        };
        let w1 = rand_vec(&mut rng, d * f);
        let w2 = rand_vec(&mut rng, f * d);
        let xe = rand_vec(&mut rng, t * d);
        let dye = rand_vec(&mut rng, t * d);
        let mono = ref_stage(
            "expert_bwd",
            &[
                lit2(&w1, d, f).unwrap(),
                lit2(&w2, f, d).unwrap(),
                lit2(&xe, t, d).unwrap(),
                lit2(&dye, t, d).unwrap(),
            ],
            None,
        )
        .unwrap();
        for nchunks in [1usize, 2, 3] {
            let mut dxe = Vec::new();
            let mut hid = Vec::new();
            let mut dpre = Vec::new();
            let mut row = 0usize;
            for c in 0..nchunks {
                let rows = t / nchunks + usize::from(c < t % nchunks);
                let out = ref_stage(
                    "expert_bwd_chunk",
                    &[
                        lit2(&w1, d, f).unwrap(),
                        lit2(&w2, f, d).unwrap(),
                        lit2(&xe[row * d..(row + rows) * d], rows, d).unwrap(),
                        lit2(&dye[row * d..(row + rows) * d], rows, d).unwrap(),
                    ],
                    None,
                )
                .unwrap();
                dxe.extend_from_slice(&out[0]);
                hid.extend_from_slice(&out[1]);
                dpre.extend_from_slice(&out[2]);
                row += rows;
            }
            assert_eq!(row, t);
            let dw = ref_stage(
                "expert_bwd_dw",
                &[
                    lit2(&xe, t, d).unwrap(),
                    lit2(&hid, t, f).unwrap(),
                    lit2(&dpre, t, f).unwrap(),
                    lit2(&dye, t, d).unwrap(),
                ],
                None,
            )
            .unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&dxe), bits(&mono[0]), "dxe diverged at {nchunks} chunks");
            assert_eq!(bits(&dw[0]), bits(&mono[1]), "dw1 diverged at {nchunks} chunks");
            assert_eq!(bits(&dw[1]), bits(&mono[2]), "dw2 diverged at {nchunks} chunks");
        }
    }

    /// The new chunked arms honor the same pooled-vs-sequential bitwise
    /// contract as every other stage.
    #[test]
    fn chunked_arms_pooled_match_sequential_bitwise() {
        let (t, d, f) = (8usize, 6usize, 7usize);
        let mut rng = Rng::new(31);
        let rand_vec = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
        };
        let w1 = rand_vec(&mut rng, d * f);
        let w2 = rand_vec(&mut rng, f * d);
        let xe = rand_vec(&mut rng, t * d);
        let dye = rand_vec(&mut rng, t * d);
        let hid = rand_vec(&mut rng, t * f);
        let dpre = rand_vec(&mut rng, t * f);
        let stages: Vec<(&str, Vec<StageArg>)> = vec![
            (
                "expert_bwd_chunk",
                vec![
                    lit2(&w1, d, f).unwrap(),
                    lit2(&w2, f, d).unwrap(),
                    lit2(&xe, t, d).unwrap(),
                    lit2(&dye, t, d).unwrap(),
                ],
            ),
            (
                "expert_bwd_dw",
                vec![
                    lit2(&xe, t, d).unwrap(),
                    lit2(&hid, t, f).unwrap(),
                    lit2(&dpre, t, f).unwrap(),
                    lit2(&dye, t, d).unwrap(),
                ],
            ),
        ];
        for (name, args) in &stages {
            let want = ref_stage(name, args, None).unwrap();
            for threads in [2usize, 4] {
                let pool = ThreadPool::with_cutoff(threads, 0);
                let got = ref_stage(name, args, Some(&pool)).unwrap();
                for (oi, (w, g)) in want.iter().zip(&got).enumerate() {
                    let same = w.len() == g.len()
                        && w.iter().zip(g).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "{name} output {oi} diverged at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn unknown_stage_and_bad_args_error() {
        assert!(ref_stage("nope", &[], None).is_err());
        assert!(ref_stage("s1_fwd", &[lit1(&[1.0])], None).is_err());
    }

    /// The per-rank threading contract: every stage produces bit-identical
    /// outputs with and without a pool (cutoff 0 so these test-sized
    /// shapes actually ride the pooled kernels). This is what lets the
    /// distributed engine hand each rank a thread budget without
    /// re-qualifying the dist numerics.
    #[test]
    fn ref_stage_pooled_matches_sequential_bitwise() {
        let (t, din, d, r, f, k) = (9usize, 5usize, 8usize, 4usize, 7usize, 3usize);
        let mut rng = Rng::new(29);
        let rand_vec = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
        };
        let w_in = rand_vec(&mut rng, din * d);
        let b_in = rand_vec(&mut rng, d);
        let wr = rand_vec(&mut rng, d * r);
        let x = rand_vec(&mut rng, t * din);
        let w_out = rand_vec(&mut rng, d * k);
        let w1 = rand_vec(&mut rng, d * f);
        let w2 = rand_vec(&mut rng, f * d);
        let xe = rand_vec(&mut rng, t * d);
        let dye = rand_vec(&mut rng, t * d);
        let dh = rand_vec(&mut rng, t * d);
        let dprobs = rand_vec(&mut rng, t * r);
        let labels: Vec<i32> = (0..t).map(|i| (i % k) as i32).collect();

        let stages: Vec<(&str, Vec<StageArg>)> = vec![
            (
                "s1_fwd",
                vec![
                    lit2(&w_in, din, d).unwrap(),
                    lit1(&b_in),
                    lit2(&wr, d, r).unwrap(),
                    lit2(&x, t, din).unwrap(),
                ],
            ),
            (
                "expert_fwd",
                vec![lit2(&w1, d, f).unwrap(), lit2(&w2, f, d).unwrap(), lit2(&xe, t, d).unwrap()],
            ),
            (
                "head_loss_bwd",
                vec![lit2(&w_out, d, k).unwrap(), lit2(&xe, t, d).unwrap(), lit1_i32(&labels)],
            ),
            (
                "expert_bwd",
                vec![
                    lit2(&w1, d, f).unwrap(),
                    lit2(&w2, f, d).unwrap(),
                    lit2(&xe, t, d).unwrap(),
                    lit2(&dye, t, d).unwrap(),
                ],
            ),
            (
                "s1_bwd",
                vec![
                    lit2(&w_in, din, d).unwrap(),
                    lit1(&b_in),
                    lit2(&wr, d, r).unwrap(),
                    lit2(&x, t, din).unwrap(),
                    lit2(&dh, t, d).unwrap(),
                    lit2(&dprobs, t, r).unwrap(),
                ],
            ),
        ];
        for (name, args) in &stages {
            let want = ref_stage(name, args, None).unwrap();
            for threads in [2usize, 4] {
                let pool = ThreadPool::with_cutoff(threads, 0);
                let got = ref_stage(name, args, Some(&pool)).unwrap();
                assert_eq!(want.len(), got.len(), "{name}: output arity");
                for (oi, (w, g)) in want.iter().zip(&got).enumerate() {
                    let same = w.len() == g.len()
                        && w.iter().zip(g).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "{name} output {oi} diverged at {threads} threads");
                }
            }
        }
    }
}
