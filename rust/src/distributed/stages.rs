//! Stage-artifact runner: manifest + per-worker compiled executables.
//!
//! Every worker owns a `StageRunner` (its own PJRT client + compiled
//! stage executables): workers are real independent "machines" that share
//! nothing but the fabric.

use anyhow::{Context, Result};
use xla::Literal;

use crate::util::json::Json;

/// Parsed `artifacts/dist/manifest.json`.
#[derive(Debug, Clone)]
pub struct DistManifest {
    pub dir: std::path::PathBuf,
    pub d_in: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub tokens_per_rank: usize,
    pub ranks: usize,
    pub files: std::collections::BTreeMap<String, String>,
    pub init_files: std::collections::BTreeMap<String, (Vec<usize>, String)>,
}

impl DistManifest {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<DistManifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("{}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("dist manifest: {e}"))?;
        let c = j.get("config").context("dist manifest: config")?;
        let g = |k: &str| c.get(k).and_then(Json::as_usize).context(k.to_string());
        let mut files = std::collections::BTreeMap::new();
        for (name, art) in j.get("artifacts").and_then(Json::as_obj).context("artifacts")? {
            files.insert(
                name.clone(),
                art.get("file").and_then(Json::as_str).context("file")?.to_string(),
            );
        }
        let mut init_files = std::collections::BTreeMap::new();
        for e in j.get("params_init").and_then(Json::as_arr).context("params_init")? {
            let name = e.get("name").and_then(Json::as_str).context("name")?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let file = e.get("file").and_then(Json::as_str).context("file")?;
            init_files.insert(name.to_string(), (shape, file.to_string()));
        }
        Ok(DistManifest {
            d_in: g("d_in")?,
            d_model: g("d_model")?,
            d_ff: g("d_ff")?,
            n_classes: g("n_classes")?,
            tokens_per_rank: g("tokens_per_rank")?,
            ranks: g("ranks")?,
            files,
            init_files,
            dir,
        })
    }

    pub fn load_init(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, file) =
            self.init_files.get(name).with_context(|| format!("no init param '{name}'"))?;
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path).with_context(|| path.display().to_string())?;
        let expect: usize = shape.iter().product::<usize>() * 4;
        anyhow::ensure!(bytes.len() == expect, "{name}: {} != {expect}", bytes.len());
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// One worker's compiled stage executables.
pub struct StageRunner {
    pub manifest: DistManifest,
    client: xla::PjRtClient,
    exes: std::collections::BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl StageRunner {
    pub fn new(manifest: DistManifest) -> Result<StageRunner> {
        let client = xla::PjRtClient::cpu()?;
        let mut exes = std::collections::BTreeMap::new();
        for (name, file) in &manifest.files {
            let path = manifest.dir.join(file);
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().context("path")?)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.insert(name.clone(), client.compile(&comp).context(name.clone())?);
        }
        Ok(StageRunner { manifest, client, exes })
    }

    /// Execute stage `name`; returns the flattened tuple outputs as f32
    /// vecs (i32 outputs are not used by any stage).
    pub fn run(&self, name: &str, args: &[Literal]) -> Result<Vec<Vec<f32>>> {
        let exe = self.exes.get(name).with_context(|| format!("no stage '{name}'"))?;
        // leak-free path: execute() leaks its input device buffers (see
        // runtime::engine::exec_leakfree); upload via owned buffers.
        let mut bufs = Vec::with_capacity(args.len());
        for lit in args {
            bufs.push(self.client.buffer_from_host_literal(None, lit)?);
        }
        let res = exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        let parts = res[0][0].to_literal_sync()?.to_tuple()?;
        parts.into_iter().map(|p| Ok(p.to_vec::<f32>()?)).collect()
    }
}

pub fn lit2(data: &[f32], r: usize, c: usize) -> Result<Literal> {
    Ok(Literal::vec1(data).reshape(&[r as i64, c as i64])?)
}

pub fn lit1(data: &[f32]) -> Literal {
    Literal::vec1(data)
}

pub fn lit1_i32(data: &[i32]) -> Literal {
    Literal::vec1(data)
}
