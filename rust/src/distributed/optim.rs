//! Host-side Adam for the distributed engine's f32 parameter buffers.
//!
//! In the distributed engine the optimizer lives in Rust (the stage
//! artifacts only compute gradients): dense parameters receive identical
//! updates on every rank (their gradients were all-reduced), expert
//! parameters update locally -- exactly the DeepSpeed MoE state layout the
//! paper trains with.

/// Adam with bias correction; beta defaults match the paper (Section 4.1).
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Adam { lr, b1: 0.9, b2: 0.99, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One update step. `params` and `grad` must have the fixed length
    /// given at construction.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g;
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn steps_taken(&self) -> i32 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // minimize f(x) = (x-3)^2 -- Adam should get close to 3.
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn identical_grads_give_identical_updates() {
        // the dense-replication invariant: same grads + same state => same params
        let mut a = vec![1.0f32, -2.0];
        let mut b = vec![1.0f32, -2.0];
        let mut oa = Adam::new(2, 0.01);
        let mut ob = Adam::new(2, 0.01);
        for s in 0..50 {
            let g = vec![(s as f32).sin(), (s as f32).cos()];
            oa.step(&mut a, &g);
            ob.step(&mut b, &g);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn zero_grad_is_noop_direction() {
        let mut x = vec![5.0f32];
        let mut opt = Adam::new(1, 0.1);
        opt.step(&mut x, &[0.0]);
        assert!((x[0] - 5.0).abs() < 1e-6);
    }
}
