//! The distributed MoE training engine: real data movement, real skips.
//!
//! N worker threads, one per simulated machine. Each worker owns its
//! resident expert's parameters and a full replica of the dense
//! parameters, runs the AOT stage artifacts (`artifacts/dist/`) on its own
//! PJRT client, and exchanges *actual token tensors* with the other
//! workers through a [`ThreadFabric`] two-phase flat-buffer all-to-all
//! (counts first, then exactly-sized zero-copy payloads -- see the wire
//! format in `moe`). The
//! [`DistCoordinator`] broadcasts the per-step Gating Dropout decision;
//! on a dropped step the all-to-alls are genuinely not executed (and on a
//! Gate-Expert-Drop step the expert stage isn't either), so wallclock
//! savings here are *measured*, not modeled.
//!
//! This engine exercises the paper's full control/data path end to end:
//! fwd stages, cross-rank dispatch, capacity admission, return combine,
//! and the manual backward through both all-to-alls (see
//! `python/compile/dist_stages.py` for the stage algebra), plus dense-grad
//! all-reduce and host-side Adam.
//!
//! The pure-Rust stage math is threaded through the same
//! `tensor::mm`/`mm_at`/`mm_bt` seam as the single-process engines: each
//! rank attaches a persistent `tensor::ThreadPool` sized by the per-rank
//! budget (`DistRunConfig::threads`; explicit = workers per rank, auto =
//! machine parallelism divided across ranks). The pooled kernels are
//! bit-identical to the sequential ones, so the budget changes wall
//! time, never losses -- pinned by
//! `tests/integration_distributed.rs::dist_losses_bit_identical_across_thread_budgets`.
//!
//! With `DistRunConfig::overlap_chunks > 1` the engine splits the expert
//! capacity into fixed contiguous chunks and pipelines the return / dye /
//! dxe all-to-all legs against per-chunk expert compute through
//! [`ThreadFabric`]'s chunked handles: the fabric ledger then charges
//! `max(comm, compute)` per pipeline stage instead of their sum, and
//! reports the hidden-communication fraction. The schedule is
//! bit-identical to serial at any chunk count -- only modeled timing
//! changes (pinned by `tests/overlap.rs`). See `docs/ARCHITECTURE.md`
//! ("distributed" layer) for the 4-leg schedule and the timing-model
//! contract.
//!
//! The same per-rank loop also runs over real sockets: `repro dist
//! --fabric tcp` joins a [`NetFabric`](crate::collective::NetFabric)
//! mesh as ONE process per rank ([`DistEngine::run_net`]), and
//! `--fabric tcp-local` ([`DistEngine::run_tcp_local`]) spawns the
//! whole world as child processes over loopback, collecting rank 0's
//! machine-readable [`NetRunReport`] result line. Fixed-seed losses and
//! the merged `a2a_ops`/`a2a_bytes`/`counts_ops` are bit-identical
//! between the two fabrics (pinned by `tests/net_parity.rs`); the TCP
//! path adds *measured* `wall_a2a_nanos`/`wall_bytes` beside the
//! modeled times.
//!
//! [`ThreadFabric`]: crate::collective::ThreadFabric

mod engine;
mod optim;
mod stages;
mod task;

pub use engine::{
    policy_flag, DistEngine, DistRunConfig, DistRunResult, NetOpts, NetRunReport,
};
pub use optim::Adam;
pub use stages::{DistManifest, StageRunner};
pub use task::ClusterTask;
