//! Worker threads + the full distributed training step.
//!
//! Forward/backward dataflow per rank (see dist_stages.py for the stage
//! algebra and mod.rs for the step diagram):
//!
//!   s1_fwd -> route (gated / hash / LOCAL on dropped steps)
//!          -> [all-to-all]            (skipped when the decision drops)
//!          -> expert_fwd              (skipped on Gate-Expert-Drop)
//!          -> [all-to-all back] -> y = h + gate*ye
//!          -> head_loss_bwd -> dy
//!          -> [all-to-all dye] -> expert_bwd -> [all-to-all dxe]
//!          -> s1_bwd -> all_reduce(dense grads) -> host Adam
//!
//! With `overlap_chunks > 1` the expert slot space is split into fixed
//! contiguous chunks and the return/dye/dxe all-to-all legs are
//! *pipelined* against the expert math: the return-leg pack of chunk `i`
//! is posted while `expert_fwd` of chunk `i+1` runs (and symmetrically
//! for the backward legs). Chunk boundaries are identical on every rank
//! and all f32 accumulation keeps the serial order -- the pipelined
//! schedule is **bit-identical** to `overlap_chunks = 1`; only the
//! modeled step time changes (`FabricStats::overlapped_ticks`). See
//! `docs/ARCHITECTURE.md` ("distributed" layer) for the schedule and the
//! slot-order invariant it rides.
//!
//! Expert parameters never leave their rank (expert parallelism); dense
//! parameters stay bit-identical across ranks because they see identical
//! all-reduced gradients -- asserted after every run.

use std::net::TcpListener;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::collective::net::{fnv1a64, NetConfig, NetFabric};
use crate::collective::{Collective, Fabric, FabricStats, OverlapKind, ThreadFabric};
use crate::coordinator::{Decision, DistCoordinator, Policy};
use crate::moe;
use crate::netmodel::{Cluster, V100_IB100};
use crate::runtime::tensor::{
    init_kernel_kind, resolve_seq_cutoff, resolve_threads_explicit, ThreadPool,
};
use crate::topology::Topology;
use crate::util::rng::Rng;

use super::optim::Adam;
use super::stages::{lit1, lit1_i32, lit2, DistManifest, StageRunner};
use super::task::ClusterTask;

#[derive(Debug, Clone)]
pub struct DistRunConfig {
    pub artifact_dir: String,
    pub n_ranks: usize,
    pub steps: u64,
    pub policy: Policy,
    pub seed: u64,
    pub lr: f32,
    /// Worker threads PER RANK for the pure-Rust stage math (each rank
    /// attaches a persistent `tensor::ThreadPool` to its `StageRunner`).
    /// `0` = auto: divide the machine's available parallelism across the
    /// ranks -- which are already `ThreadFabric` threads -- so the sim
    /// never oversubscribes by default. An explicit value (CLI
    /// `--threads`, config `"threads"`, or the `GD_THREADS` env override)
    /// is taken as the per-rank count verbatim. Thread count never
    /// changes results: the pooled stage kernels are bit-identical to
    /// the sequential ones.
    pub threads: usize,
    /// Router for routed (non-dropped, non-hash) steps. `Top1` (the
    /// default) runs the seed's `moe::top1` scan verbatim; `TopK` /
    /// `Adaptive` send each token to multiple experts over the same
    /// two-phase wire (the counts phase already sizes variable fan-out).
    pub router: moe::Router,
    /// Pipeline depth for the return/dye/dxe all-to-all legs: the expert
    /// slot space is split into this many fixed contiguous chunks and
    /// each chunk's wire traffic is posted while the next chunk's expert
    /// math runs. `1` (the default) is the serial schedule. Bit-identical
    /// at every setting -- only the modeled step time moves. Values > 1
    /// require the synthetic manifest (the XLA stage artifacts are
    /// compiled for full-capacity shapes).
    pub overlap_chunks: usize,
    /// Cluster used to model step time (comm spans via `netmodel`
    /// all-to-all/all-reduce costs, compute spans via `compute_time`).
    /// `None` disables the timing model: `FabricStats` keeps byte/op
    /// counts but reports zero modeled time.
    pub cluster: Option<Cluster>,
}

impl Default for DistRunConfig {
    fn default() -> Self {
        // Without the XLA stage artifacts compiled in, default to the
        // deterministic synthetic dist model (pure-Rust stage runner).
        let artifact_dir = if cfg!(feature = "backend-xla") {
            "artifacts/dist"
        } else {
            "synthetic"
        };
        DistRunConfig {
            artifact_dir: artifact_dir.into(),
            n_ranks: 4,
            steps: 30,
            policy: Policy::Baseline,
            seed: 7,
            lr: 2e-3,
            threads: 0,
            router: moe::Router::Top1,
            overlap_chunks: 1,
            cluster: Some(V100_IB100),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DistRunResult {
    /// Rank-mean loss per step.
    pub losses: Vec<f32>,
    pub fabric: FabricStats,
    pub wall_secs: f64,
    /// (decision.drop, rank-0 measured step seconds) per step.
    pub step_wall: Vec<(bool, f64)>,
    /// Dense parameters bit-identical across ranks at the end?
    pub dense_consistent: bool,
    pub observed_drop_rate: f64,
    /// Rank-0 dense parameters followed by every rank's expert
    /// parameters: the full final model, for bit-parity tests (e.g. the
    /// `overlap_chunks` invariance suite compares these to_bits).
    pub param_fingerprint: Vec<f32>,
}

/// Fixed contiguous chunk bounds over the expert slot space `[0, cap)`:
/// `c` half-open ranges with sizes differing by at most one, clamped to
/// `1..=cap` chunks. `cap` is identical on every rank (tokens_per_rank x
/// router fan-out bound, padding included), so chunk membership is
/// SPMD-consistent without any extra wire phase.
fn chunk_bounds(cap: usize, c: usize) -> Vec<(usize, usize)> {
    let c = c.clamp(1, cap.max(1));
    let (base, extra) = (cap / c, cap % c);
    let mut out = Vec::with_capacity(c);
    let mut lo = 0;
    for i in 0..c {
        let hi = lo + base + usize::from(i < extra);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

struct WorkerState {
    rank: usize,
    topo: Topology,
    runner: StageRunner,
    router: moe::Router,
    /// Pipeline depth for the chunked wire legs (1 = serial schedule).
    overlap_chunks: usize,
    /// Timing model for compute spans; `None` charges zero compute.
    cluster: Option<Cluster>,
    // dense (replicated)
    w_in: Vec<f32>,
    b_in: Vec<f32>,
    wr: Vec<f32>,
    w_out: Vec<f32>,
    // resident expert
    w1: Vec<f32>,
    w2: Vec<f32>,
    // host optimizers
    o_win: Adam,
    o_bin: Adam,
    o_wr: Adam,
    o_wout: Adam,
    o_w1: Adam,
    o_w2: Adam,
}

impl WorkerState {
    fn new(
        rank: usize,
        m: DistManifest,
        lr: f32,
        threads: usize,
        seq_cutoff: usize,
        router: moe::Router,
        overlap_chunks: usize,
        cluster: Option<Cluster>,
    ) -> Result<WorkerState> {
        let topo = Topology::new(m.ranks, m.ranks); // one expert per rank
        let w_in = m.load_init("w_in")?;
        let b_in = m.load_init("b_in")?;
        let wr = m.load_init("wr")?;
        let w_out = m.load_init("w_out")?;
        let w1 = m.load_init(&format!("expert{rank}_w1"))?;
        let w2 = m.load_init(&format!("expert{rank}_w2"))?;
        let mut runner = StageRunner::new(m)?;
        if threads > 1 {
            // this rank's slice of the machine: persistent workers under
            // the ThreadFabric rank thread, bit-neutral by the kernel
            // parity contract (cutoff resolved once by the engine, so a
            // bad GD_SEQ_CUTOFF errors at launch, not as a rank panic)
            runner.set_thread_pool(ThreadPool::with_cutoff(threads, seq_cutoff));
        }
        Ok(WorkerState {
            rank,
            topo,
            router,
            overlap_chunks,
            cluster,
            o_win: Adam::new(w_in.len(), lr),
            o_bin: Adam::new(b_in.len(), lr),
            o_wr: Adam::new(wr.len(), lr),
            o_wout: Adam::new(w_out.len(), lr),
            o_w1: Adam::new(w1.len(), lr),
            o_w2: Adam::new(w2.len(), lr),
            w_in,
            b_in,
            wr,
            w_out,
            w1,
            w2,
            runner,
        })
    }

    /// Modeled seconds for `flops` of expert math on the configured
    /// cluster (zero with no cluster attached). The expert stages run
    /// over the FULL slot range (padding included) on every rank, so
    /// these spans are identical across ranks and across chunk counts.
    fn compute_secs(&self, flops: f64) -> f64 {
        self.cluster.map_or(0.0, |c| c.compute_time(flops))
    }

    /// One full training step; returns this rank's loss.
    fn step(
        &mut self,
        fabric: &Fabric,
        decision: Decision,
        x: &[f32],
        labels: &[i32],
        token_ids: &[u32],
    ) -> Result<f32> {
        let m = &self.runner.manifest;
        let (din, d, t, r) = (m.d_in, m.d_model, m.tokens_per_rank, m.ranks);
        // Expert buffer rows: the per-token share times the router's
        // fan-out bound on routed steps (x1 under any k=1 routing --
        // identical to the seed's `cap = t`). Dropped/hashed steps force
        // one slot per token, so their capacity stays the seed's
        // regardless of the configured router.
        let kmax = if decision.drop || decision.hash_route { 1 } else { self.router.max_k() };
        let cap = t * kmax;
        let stride = moe::HEADER + d;

        // ---- stage 1 forward -------------------------------------------------
        let out = self.runner.run(
            "s1_fwd",
            &[
                lit2(&self.w_in, din, d)?,
                lit1(&self.b_in),
                lit2(&self.wr, d, r)?,
                lit2(x, t, din)?,
            ],
        )?;
        let (h, probs) = (&out[0], &out[1]);

        // ---- routing ---------------------------------------------------------
        // CSR assignment: dropped/hashed steps force one expert per token
        // (offsets 0..=t, the seed layout); routed steps go through the
        // configured router (Top1 runs the seed's `moe::top1` scan).
        let assign: moe::RouteAssign = if decision.drop {
            // Gating Dropout: every token to the rank's own expert.
            let e: Vec<usize> = (0..t).map(|_| self.rank).collect();
            let g: Vec<f32> = (0..t).map(|i| moe::gate_of(probs, r, i, self.rank)).collect();
            moe::RouteAssign::from_single(e, g)
        } else if decision.hash_route {
            // Hash-Layer routing hashes the token's VOCAB id (the
            // `model._hash_ids` convention), not its batch position.
            let (e, g) = moe::hash_route(token_ids, probs, r);
            moe::RouteAssign::from_single(e, g)
        } else {
            self.router.route(probs, t, r)
        };

        // ---- dispatch (+all-to-all unless dropped) ---------------------------
        let (xe, admitted) = if decision.drop {
            if decision.expert_skip {
                (Vec::new(), Vec::new())
            } else {
                // local-only: xe = h rows in token order, slot = token idx
                let admitted: Vec<moe::Admitted> = (0..t)
                    .map(|i| moe::Admitted {
                        src_rank: self.rank,
                        src_idx: i,
                        gate: assign.gates[i],
                        slot: i,
                        local_expert: 0,
                    })
                    .collect();
                (h.clone(), admitted)
            }
        } else {
            // two-phase flat dispatch: counts first, then exactly-sized
            // contiguous buffers through the row-counted all-to-all (one
            // wire row per (token, slot) -- variable fan-out rides the
            // same counts phase).
            let counts = self.topo.owner_counts(&assign.experts);
            let recv_rows = fabric.all_to_all_counts(self.rank, &counts)?;
            let packed = moe::route_pack_k(&self.topo, h, d, &assign, &counts);
            let arrivals =
                fabric.all_to_all_rows(self.rank, packed, &counts, &recv_rows, stride, "dispatch")?;
            moe::route_admit(self.rank, &self.topo, &arrivals, d, cap)
        };

        // ---- expert forward + combine (+return all-to-all unless dropped) ----
        // admitted tokens per home rank: shared by the return leg and both
        // backward wire legs (they all ride the admission edges).
        let ret_counts: Vec<usize> = if decision.drop {
            Vec::new()
        } else {
            moe::return_counts(&self.topo, &admitted)
        };
        // own (token, slot) rows admitted per owner rank: the return-leg
        // counts phase delivers exactly this, and both backward wire legs
        // reuse it (empty on dropped / expert-skipped steps, where no
        // wire runs).
        let mut surviving: Vec<usize> = Vec::new();
        // Pipeline chunk bounds over the slot space. Local (dropped) steps
        // never chunk: there is no wire to hide, so they keep the
        // monolithic stages (and work on XLA artifacts unconditionally).
        let f = m.d_ff;
        let bounds = chunk_bounds(cap, if decision.drop { 1 } else { self.overlap_chunks });
        // ret: weighted combine + per-arrival-row records on the home rank.
        let ret: moe::ReturnedK = if !decision.runs_expert() {
            moe::ReturnedK { combined: vec![0.0; t * d], raw: Vec::new(), rows: Vec::new() }
        } else if decision.drop {
            // local: token i <-> slot i, one row per token
            let out = self.runner.run(
                "expert_fwd",
                &[
                    lit2(&self.w1, d, f)?,
                    lit2(&self.w2, f, d)?,
                    lit2(&xe, cap, d)?,
                ],
            )?;
            let ye = out.into_iter().next().unwrap();
            let mut out = moe::ReturnedK {
                combined: vec![0.0; t * d],
                rows: (0..t)
                    .map(|i| moe::RetRow {
                        token: i,
                        owner: self.rank,
                        slot: i,
                        gate: assign.gates[i],
                    })
                    .collect(),
                raw: ye,
            };
            for i in 0..t {
                for j in 0..d {
                    out.combined[i * d + j] = assign.gates[i] * out.raw[i * d + j];
                }
            }
            out
        } else {
            // counts phase first (it needs only the admission records):
            // the home rank cannot predict how many of its rows survived
            // capacity admission on the owners.
            let recv_rows = fabric.all_to_all_counts(self.rank, &ret_counts)?;
            // Slot-order invariant the chunked pack rides: one expert per
            // rank means `route_admit` fills slots with a sequential
            // counter, so `admitted[i].slot == i` and a slot range is an
            // `admitted` prefix range.
            debug_assert!(
                admitted.iter().enumerate().all(|(i, a)| a.slot == i),
                "slot-order invariant violated: chunked packing would reorder rows"
            );
            // Pipelined return leg: expert_fwd of chunk c runs, its pack
            // is posted, and chunk c+1's math runs while those rows are
            // in flight (Send pairing: comm chunk c hides behind compute
            // chunk c+1). expert_fwd costs two matmuls = 4*rows*d*f flops.
            let mut pipe = fabric.a2a_pipelined(self.rank, OverlapKind::Send, true, "return");
            for &(lo, hi) in &bounds {
                let rows = hi - lo;
                let out = self.runner.run(
                    "expert_fwd",
                    &[
                        lit2(&self.w1, d, f)?,
                        lit2(&self.w2, f, d)?,
                        lit2(&xe[lo * d..hi * d], rows, d)?,
                    ],
                )?;
                let msgs = pack_admitted_chunk(&admitted, lo, hi, &out[0], d, r);
                pipe.post_chunk(msgs, self.compute_secs(4.0 * (rows * d * f) as f64))?;
            }
            // Drain and reassemble full per-source buffers in chunk order
            // (= the serial pack order, by the slot-order invariant), so
            // the per-token `+=` combine accumulates in the serial order.
            let mut arrivals: Vec<Vec<f32>> = vec![Vec::new(); r];
            for _ in &bounds {
                for (src, part) in pipe.recv_chunk()?.into_iter().enumerate() {
                    arrivals[src].extend(part);
                }
            }
            pipe.finish()?;
            for (src, buf) in arrivals.iter().enumerate() {
                crate::ensure!(
                    buf.len() == recv_rows[src] * stride,
                    "return-leg chunks disagree with the counts phase (src {src})"
                );
            }
            surviving = recv_rows;
            moe::return_unpack_k(&arrivals, t, d)
        };
        let mut y = vec![0f32; t * d];
        for i in 0..t * d {
            y[i] = h[i] + ret.combined[i];
        }

        // ---- head + loss + dy -------------------------------------------------
        let out = self.runner.run(
            "head_loss_bwd",
            &[
                lit2(&self.w_out, d, m.n_classes)?,
                lit2(&y, t, d)?,
                lit1_i32(labels),
            ],
        )?;
        let loss = out[0][0];
        let dy = &out[1];
        let dw_out = out[2].clone();

        // ---- backward through combine / expert / dispatch --------------------
        let mut dh: Vec<f32> = dy.clone(); // residual path
        let mut dprobs = vec![0f32; t * r];
        let (dw1, dw2): (Vec<f32>, Vec<f32>) = if decision.runs_expert() {
            // cotangents for expert outputs, one per returned (token, slot)
            // row; scatter each onto its CSR slot (one expert per rank, so
            // a (token, owner) pair names at most one slot) and push the
            // gate gradients through the router VJP -- the raw-prob gate
            // at k=1 (the seed's scatter), renormalized-softmax at k>=2.
            let mut dgates = vec![0f32; assign.n_slots()];
            for (ri, row) in ret.rows.iter().enumerate() {
                let mut acc = 0f32;
                for j in 0..d {
                    acc += dy[row.token * d + j] * ret.raw[ri * d + j];
                }
                for s in assign.range(row.token) {
                    if self.topo.owner_of(assign.experts[s]) == row.owner {
                        dgates[s] = acc;
                        break;
                    }
                }
            }
            moe::router_vjp(&assign, probs, &dgates, r, &mut dprobs);
            // Both backward wire legs ride the admission edges, so no
            // counts phase goes on the wire: this rank *receives* one dye
            // row / *sends* one dxe row per token it admitted
            // (`ret_counts`), and *sends* one dye row / *receives* one
            // dxe row per own token that survived admission (`surviving`,
            // already delivered by the return-leg counts phase).
            if decision.drop {
                // local: slot i = token i, monolithic expert backward
                let mut dye_buf = vec![0f32; cap * d];
                for i in 0..t {
                    for j in 0..d {
                        dye_buf[i * d + j] = assign.gates[i] * dy[i * d + j];
                    }
                }
                let out = self.runner.run(
                    "expert_bwd",
                    &[
                        lit2(&self.w1, d, f)?,
                        lit2(&self.w2, f, d)?,
                        lit2(&xe, cap, d)?,
                        lit2(&dye_buf, cap, d)?,
                    ],
                )?;
                for i in 0..t * d {
                    dh[i] += out[0][i];
                }
                (out[1].clone(), out[2].clone())
            } else {
                // ---- pipelined dye -> expert_bwd -> dxe ---------------
                // Per-owner row-index lists into ret.rows: each owner's
                // subsequence is slot-ascending (owners admit with a
                // sequential fill counter), so slot chunk c takes a
                // prefix of what remains per owner, and chunk-order
                // concatenation reproduces the serial dye pack exactly.
                let mut by_owner: Vec<Vec<usize>> = vec![Vec::new(); r];
                for (ri, row) in ret.rows.iter().enumerate() {
                    by_owner[row.owner].push(ri);
                }
                let bwd_secs: Vec<f64> = bounds
                    .iter()
                    .map(|&(lo, hi)| self.compute_secs(6.0 * ((hi - lo) * d * f) as f64))
                    .collect();
                // the dw tail runs once after the chunk loop, while the
                // in-flight dxe chunks drain: fold its span into the last
                // chunk's compute on the Send pipe
                let dw_secs = self.compute_secs(4.0 * (cap * d * f) as f64);
                // dye leg, Recv pairing: chunk c+1's rows are in flight
                // while expert_bwd of chunk c runs. All chunks post up
                // front (they need only dy + the returned-row records).
                // charge_compute stays false: the dxe pipe charges these
                // same expert-bwd spans, and the two legs run in opposite
                // directions (full duplex), so each may hide behind the
                // same compute window without double-charging compute.
                let mut dye_pipe = fabric.a2a_pipelined(self.rank, OverlapKind::Recv, false, "dye");
                let mut cursor = vec![0usize; r];
                for (c, &(_, hi)) in bounds.iter().enumerate() {
                    let mut msgs: Vec<Vec<f32>> = vec![Vec::new(); r];
                    for (owner, msg) in msgs.iter_mut().enumerate() {
                        while let Some(&ri) = by_owner[owner].get(cursor[owner]) {
                            let row = &ret.rows[ri];
                            if row.slot >= hi {
                                break;
                            }
                            msg.extend_from_slice(&[
                                row.slot as f32,
                                row.token as f32,
                                row.gate,
                            ]);
                            msg.extend(
                                dy[row.token * d..(row.token + 1) * d]
                                    .iter()
                                    .map(|&v| row.gate * v),
                            );
                            cursor[owner] += 1;
                        }
                    }
                    dye_pipe.post_chunk(msgs, bwd_secs[c])?;
                }
                let mut dye_buf = vec![0f32; cap * d];
                let mut dye_got = vec![0usize; r];
                let mut dxe_pipe = fabric.a2a_pipelined(self.rank, OverlapKind::Send, true, "dxe");
                let dw12: (Vec<f32>, Vec<f32>) = if bounds.len() == 1 {
                    // serial schedule on the pipelined handles: one
                    // chunk, identical wire buffers, zero overlap credit,
                    // and the monolithic "expert_bwd" stage -- the one
                    // the XLA artifacts compile.
                    scatter_dye_chunk(&mut dye_buf, &mut dye_got, &dye_pipe.recv_chunk()?, d);
                    let out = self.runner.run(
                        "expert_bwd",
                        &[
                            lit2(&self.w1, d, f)?,
                            lit2(&self.w2, f, d)?,
                            lit2(&xe, cap, d)?,
                            lit2(&dye_buf, cap, d)?,
                        ],
                    )?;
                    let msgs = pack_admitted_chunk(&admitted, 0, cap, &out[0], d, r);
                    dxe_pipe.post_chunk(msgs, bwd_secs[0] + dw_secs)?;
                    (out[1].clone(), out[2].clone())
                } else {
                    // fused loop: receive chunk c's cotangents, run its
                    // expert-backward slice, post its dxe rows -- chunk
                    // c+1's dye rows are already in flight underneath.
                    let mut hid = Vec::with_capacity(cap * f);
                    let mut dpre = Vec::with_capacity(cap * f);
                    for (c, &(lo, hi)) in bounds.iter().enumerate() {
                        let rows = hi - lo;
                        scatter_dye_chunk(
                            &mut dye_buf,
                            &mut dye_got,
                            &dye_pipe.recv_chunk()?,
                            d,
                        );
                        let out = self.runner.run(
                            "expert_bwd_chunk",
                            &[
                                lit2(&self.w1, d, f)?,
                                lit2(&self.w2, f, d)?,
                                lit2(&xe[lo * d..hi * d], rows, d)?,
                                lit2(&dye_buf[lo * d..hi * d], rows, d)?,
                            ],
                        )?;
                        hid.extend_from_slice(&out[1]);
                        dpre.extend_from_slice(&out[2]);
                        let dw_tail = if c == bounds.len() - 1 { dw_secs } else { 0.0 };
                        let msgs = pack_admitted_chunk(&admitted, lo, hi, &out[0], d, r);
                        dxe_pipe.post_chunk(msgs, bwd_secs[c] + dw_tail)?;
                    }
                    // weight gradients: ONE pass over the concatenated
                    // buffers, so dw1/dw2 keep the monolithic token-axis
                    // accumulation order bit for bit.
                    let dw = self.runner.run(
                        "expert_bwd_dw",
                        &[
                            lit2(&xe, cap, d)?,
                            lit2(&hid, cap, f)?,
                            lit2(&dpre, cap, f)?,
                            lit2(&dye_buf, cap, d)?,
                        ],
                    )?;
                    let mut it = dw.into_iter();
                    (it.next().unwrap(), it.next().unwrap())
                };
                dye_pipe.finish()?;
                for (src, &got) in dye_got.iter().enumerate() {
                    crate::ensure!(
                        got == ret_counts[src] * stride,
                        "dye-leg chunks disagree with the admission counts (src {src})"
                    );
                }
                // dxe receive: reassemble full per-source buffers first
                // (chunk order = the serial pack order), then scatter in
                // source-major order -- `dh +=` rows from different
                // sources can hit the same token, so the accumulation
                // order must stay exactly serial.
                let mut arrivals: Vec<Vec<f32>> = vec![Vec::new(); r];
                for _ in &bounds {
                    for (src, part) in dxe_pipe.recv_chunk()?.into_iter().enumerate() {
                        arrivals[src].extend(part);
                    }
                }
                dxe_pipe.finish()?;
                for (src, buf) in arrivals.iter().enumerate() {
                    crate::ensure!(
                        buf.len() == surviving[src] * stride,
                        "dxe-leg chunks disagree with the return counts (src {src})"
                    );
                }
                for msg in &arrivals {
                    for tok in msg.chunks_exact(stride) {
                        let i = tok[1] as usize;
                        for j in 0..d {
                            dh[i * d + j] += tok[moe::HEADER + j];
                        }
                    }
                }
                dw12
            }
        } else {
            (vec![0f32; self.w1.len()], vec![0f32; self.w2.len()])
        };

        // ---- stage-1 backward -------------------------------------------------
        let out = self.runner.run(
            "s1_bwd",
            &[
                lit2(&self.w_in, din, d)?,
                lit1(&self.b_in),
                lit2(&self.wr, d, r)?,
                lit2(x, t, din)?,
                lit2(&dh, t, d)?,
                lit2(&dprobs, t, r)?,
            ],
        )?;
        let (mut dw_in, mut db_in, mut dwr) = (out[0].clone(), out[1].clone(), out[2].clone());

        // ---- dense all-reduce + host Adam -------------------------------------
        let mut dw_out = dw_out;
        fabric.all_reduce_sum(self.rank, &mut dw_in)?;
        fabric.all_reduce_sum(self.rank, &mut db_in)?;
        fabric.all_reduce_sum(self.rank, &mut dwr)?;
        fabric.all_reduce_sum(self.rank, &mut dw_out)?;
        let scale = 1.0 / r as f32;
        for g in [&mut dw_in, &mut db_in, &mut dwr, &mut dw_out] {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
        self.o_win.step(&mut self.w_in, &dw_in);
        self.o_bin.step(&mut self.b_in, &db_in);
        self.o_wr.step(&mut self.wr, &dwr);
        self.o_wout.step(&mut self.w_out, &dw_out);
        if decision.runs_expert() {
            self.o_w1.step(&mut self.w1, &dw1);
            self.o_w2.step(&mut self.w2, &dw2);
        }
        Ok(loss)
    }
}

/// Pack the admitted rows of slot chunk `[lo, hi)` into per-destination
/// wire buffers of `[slot, src_idx, gate, row..]`, with the payload row
/// taken from `data_c`, a chunk-local `[hi-lo, d]` buffer. Relies on the
/// slot-order invariant (`admitted[i].slot == i` at one expert per rank):
/// a slot range is an `admitted` prefix range, and iterating it in order
/// means concatenating chunk buffers per destination reproduces the
/// serial pack byte for byte.
fn pack_admitted_chunk(
    admitted: &[moe::Admitted],
    lo: usize,
    hi: usize,
    data_c: &[f32],
    d: usize,
    n: usize,
) -> Vec<Vec<f32>> {
    let (a_lo, a_hi) = (lo.min(admitted.len()), hi.min(admitted.len()));
    let mut msgs: Vec<Vec<f32>> = vec![Vec::new(); n];
    for a in &admitted[a_lo..a_hi] {
        let msg = &mut msgs[a.src_rank];
        msg.extend_from_slice(&[a.slot as f32, a.src_idx as f32, a.gate]);
        msg.extend_from_slice(&data_c[(a.slot - lo) * d..(a.slot - lo + 1) * d]);
    }
    msgs
}

/// Scatter one chunk of dye arrivals into the expert cotangent buffer
/// and tally the received f32 elements per source (validated against the
/// admission counts after the last chunk). Pure per-slot assignment --
/// each admitted slot receives exactly one cotangent row -- so scattering
/// chunk by chunk cannot reorder any f32 accumulation.
fn scatter_dye_chunk(buf: &mut [f32], got: &mut [usize], arrivals: &[Vec<f32>], d: usize) {
    let stride = moe::HEADER + d;
    for (src, msg) in arrivals.iter().enumerate() {
        got[src] += msg.len();
        for tok in msg.chunks_exact(stride) {
            let slot = tok[0] as usize;
            buf[slot * d..(slot + 1) * d].copy_from_slice(&tok[moe::HEADER..]);
        }
    }
}

/// What one rank's run loop produces: (losses, per-step walls, dense
/// fingerprint, resident expert fingerprint, observed drop rate).
type WorkerOut = (Vec<f32>, Vec<(bool, f64)>, Vec<f32>, Vec<f32>, f64);

/// One rank's whole training loop, fabric-agnostic: the thread engine
/// runs this on N threads over one shared `Fabric::Thread`, the net
/// engine runs it once per process over its `Fabric::Net`. SPMD: every
/// rank must execute the identical collective sequence.
///
/// `die_at_step`: fault injection for the net path -- the process exits
/// hard (code 3) right before that step's collectives, no goodbye, so
/// surviving ranks must surface the dead peer by read timeout.
#[allow(clippy::too_many_arguments)]
fn run_rank(
    rank: usize,
    fabric: Arc<Fabric>,
    manifest: DistManifest,
    cfg: &DistRunConfig,
    per_rank_threads: usize,
    seq_cutoff: usize,
    task: &ClusterTask,
    die_at_step: Option<u64>,
) -> Result<WorkerOut> {
    let mut w = WorkerState::new(
        rank,
        manifest,
        cfg.lr,
        per_rank_threads,
        seq_cutoff,
        cfg.router,
        cfg.overlap_chunks,
        cfg.cluster,
    )?;
    let mut coord = DistCoordinator::new(rank, fabric.clone(), cfg.policy, cfg.seed);
    let mut rng = Rng::new(cfg.seed).fork(100 + rank as u64);
    let mut losses = Vec::new();
    let mut walls = Vec::new();
    let t = w.runner.manifest.tokens_per_rank;
    for step in 0..cfg.steps {
        if die_at_step == Some(step) {
            std::process::exit(3);
        }
        let decision = coord.decide(step)?;
        let (x, labels, token_ids) = task.sample(rank, t, &mut rng);
        let t0 = Instant::now();
        let mut loss = w.step(&fabric, decision, &x, &labels, &token_ids)?;
        walls.push((decision.drop, t0.elapsed().as_secs_f64()));
        // rank-mean loss for reporting: diagnostics only, so it
        // must stay OUT of the training-communication stats
        let mut lbuf = vec![loss];
        fabric.all_reduce_sum_unaccounted(rank, &mut lbuf)?;
        loss = lbuf[0] / cfg.n_ranks as f32;
        losses.push(loss);
    }
    let drop_rate = coord
        .audit_log()
        .iter()
        .filter(|&&b| crate::coordinator::Decision::decode(b).drop)
        .count() as f64
        / cfg.steps.max(1) as f64;
    // dense-param fingerprint for the consistency check, plus
    // this rank's resident expert for the full-model one
    let mut fp = w.w_in.clone();
    fp.extend_from_slice(&w.wr);
    fp.extend_from_slice(&w.w_out);
    let mut efp = w.w1.clone();
    efp.extend_from_slice(&w.w2);
    Ok((losses, walls, fp, efp, drop_rate))
}

fn f32s_le(v: &[f32]) -> Vec<u8> {
    let mut b = Vec::with_capacity(v.len() * 4);
    for x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b
}

impl DistRunResult {
    /// FNV-1a 64 over the little-endian bits of the full final model --
    /// the compact cross-process parity token (`fp_hash` in the net
    /// path's result line).
    pub fn fingerprint_hash(&self) -> u64 {
        fnv1a64(&f32s_le(&self.param_fingerprint))
    }
}

/// How one process joins (or locally launches) the TCP fabric.
#[derive(Debug, Clone)]
pub struct NetOpts {
    pub rank: usize,
    pub world: usize,
    /// Rank 0's rendezvous address, `HOST:PORT`.
    pub coord: String,
    pub timeout_ms: u64,
    pub retries: u32,
    pub backoff_ms: u64,
    /// Fault injection: this process exits hard right before the given
    /// step (under `tcp-local`, applied to the last rank).
    pub die_at_step: Option<u64>,
}

impl NetOpts {
    pub fn new(rank: usize, world: usize, coord: impl Into<String>) -> NetOpts {
        NetOpts {
            rank,
            world,
            coord: coord.into(),
            timeout_ms: 10_000,
            retries: 80,
            backoff_ms: 25,
            die_at_step: None,
        }
    }
}

/// What a `--fabric tcp` run reports on rank 0 -- exactly the fields the
/// ThreadFabric parity bar compares. The `tcp-local` launcher parses it
/// back from the rank-0 child's stdout via [`NetRunReport::result_line`].
#[derive(Debug, Clone, PartialEq)]
pub struct NetRunReport {
    /// Rank-mean loss per step (identical on every rank by construction;
    /// parity asserts the f32 bits against the thread run).
    pub losses: Vec<f32>,
    /// Per-rank local stats merged with [`FabricStats::merge_ranks`].
    pub fabric: FabricStats,
    pub dense_consistent: bool,
    /// FNV-1a 64 of the full final model in the thread-mode
    /// `param_fingerprint` order (rank-0 dense, then every expert).
    pub fingerprint_hash: u64,
    pub observed_drop_rate: f64,
}

impl NetRunReport {
    /// One machine-readable stdout line (`GDNET_RESULT v1 ...`). Floats
    /// travel as hex bit patterns so the round trip is exact.
    pub fn result_line(&self) -> String {
        let losses: Vec<String> =
            self.losses.iter().map(|l| format!("{:08x}", l.to_bits())).collect();
        let stats: String =
            self.fabric.to_le_bytes().iter().map(|b| format!("{b:02x}")).collect();
        format!(
            "GDNET_RESULT v1 losses={} stats={} dense={} fp_hash={:016x} drop_rate={:016x}",
            if losses.is_empty() { "-".to_string() } else { losses.join(",") },
            stats,
            u8::from(self.dense_consistent),
            self.fingerprint_hash,
            self.observed_drop_rate.to_bits(),
        )
    }

    /// Find and parse the `GDNET_RESULT v1` line in a rank-0 transcript.
    pub fn parse_result_line(text: &str) -> Result<NetRunReport> {
        let line = text
            .lines()
            .find(|l| l.starts_with("GDNET_RESULT v1 "))
            .context("no GDNET_RESULT line in the rank-0 output")?;
        let mut kv = std::collections::HashMap::new();
        for part in line.split_whitespace().skip(2) {
            if let Some((k, v)) = part.split_once('=') {
                kv.insert(k, v);
            }
        }
        let get = |k: &str| {
            kv.get(k).copied().with_context(|| format!("GDNET_RESULT line is missing {k}="))
        };
        let losses_s = get("losses")?;
        let losses: Vec<f32> = if losses_s == "-" {
            Vec::new()
        } else {
            losses_s
                .split(',')
                .map(|h| {
                    u32::from_str_radix(h, 16)
                        .map(f32::from_bits)
                        .map_err(|e| crate::err!("bad loss bits {h:?}: {e}"))
                })
                .collect::<Result<_>>()?
        };
        let stats_s = get("stats")?;
        crate::ensure!(stats_s.len() % 2 == 0, "stats hex has odd length {}", stats_s.len());
        let stats_bytes: Vec<u8> = (0..stats_s.len() / 2)
            .map(|i| {
                u8::from_str_radix(&stats_s[2 * i..2 * i + 2], 16)
                    .map_err(|e| crate::err!("bad stats hex: {e}"))
            })
            .collect::<Result<_>>()?;
        let fp_hash = u64::from_str_radix(get("fp_hash")?, 16)
            .map_err(|e| crate::err!("bad fp_hash: {e}"))?;
        let drop_bits = u64::from_str_radix(get("drop_rate")?, 16)
            .map_err(|e| crate::err!("bad drop_rate: {e}"))?;
        Ok(NetRunReport {
            losses,
            fabric: FabricStats::from_le_bytes(&stats_bytes)?,
            dense_consistent: get("dense")? == "1",
            fingerprint_hash: fp_hash,
            observed_drop_rate: f64::from_bits(drop_bits),
        })
    }
}

/// The `--policy` flag string that `Policy::parse` maps back to exactly
/// this policy. `Policy::name()` is NOT enough: it drops the rate, and a
/// child process re-parsing "gate-drop" would silently run p=0.3.
pub fn policy_flag(p: Policy) -> String {
    match p {
        Policy::Baseline => "baseline".to_string(),
        Policy::GateDrop { p } => format!("gate-drop:{p}"),
        Policy::GateExpertDrop { p } => format!("gate-expert-drop:{p}"),
        Policy::HashLayer => "hash-layer".to_string(),
        Policy::NoAllToAll => "no-alltoall".to_string(),
    }
}

pub struct DistEngine;

impl DistEngine {
    /// Run `cfg.steps` of distributed training; returns losses + fabric
    /// accounting + per-step wallclock split by decision.
    pub fn run(cfg: &DistRunConfig) -> Result<DistRunResult> {
        let manifest = DistManifest::load(&cfg.artifact_dir)?;
        crate::ensure!(
            cfg.n_ranks == manifest.ranks,
            "artifact exported for {} ranks, requested {}",
            manifest.ranks,
            cfg.n_ranks
        );
        let n = manifest.ranks;
        crate::ensure!(cfg.overlap_chunks >= 1, "overlap_chunks must be >= 1");
        crate::ensure!(
            cfg.overlap_chunks == 1 || manifest.synthetic_seed.is_some(),
            "overlap_chunks > 1 requires the synthetic manifest: the XLA stage \
             artifacts are compiled for full-capacity shapes only"
        );
        // Per-rank thread budget for the stage math. Explicit requests
        // (CLI --threads / config "threads" / GD_THREADS env) are taken
        // as workers PER RANK; auto (0) divides the machine's available
        // parallelism across the rank threads so the default never
        // oversubscribes. Either way the bits cannot move -- the pooled
        // stage kernels are bit-identical to the sequential ones.
        let per_rank_threads = match resolve_threads_explicit(cfg.threads)? {
            Some(explicit) => explicit,
            None => (std::thread::available_parallelism().map_or(1, |p| p.get()) / n).max(1),
        };
        // resolve the cutoff and kernel kind once here so a garbage
        // GD_SEQ_CUTOFF or GD_SIMD is a clean launch error, not a panic
        // inside every rank thread
        let seq_cutoff = resolve_seq_cutoff()?;
        init_kernel_kind()?;
        let fabric = Arc::new(Fabric::Thread(ThreadFabric::with_cluster(n, cfg.cluster)));
        let task = Arc::new(ClusterTask::new(
            manifest.d_in,
            manifest.n_classes,
            n,
            cfg.seed,
        ));
        let started = Instant::now();
        let mut handles = Vec::new();
        for rank in 0..n {
            let fabric = fabric.clone();
            let task = task.clone();
            let manifest = manifest.clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || -> Result<WorkerOut> {
                run_rank(
                    rank,
                    fabric,
                    manifest,
                    &cfg,
                    per_rank_threads,
                    seq_cutoff,
                    &task,
                    None,
                )
            }));
        }
        let mut all: Vec<WorkerOut> = Vec::new();
        for h in handles {
            all.push(h.join().map_err(|_| crate::err!("worker panicked"))??);
        }
        let dense_consistent = all.windows(2).all(|w| w[0].2 == w[1].2);
        let losses = all[0].0.clone();
        let step_wall = all[0].1.clone();
        let observed_drop_rate = all[0].4;
        let mut param_fingerprint = all[0].2.clone();
        for a in &all {
            param_fingerprint.extend_from_slice(&a.3);
        }
        Ok(DistRunResult {
            losses,
            fabric: fabric.stats(),
            wall_secs: started.elapsed().as_secs_f64(),
            step_wall,
            dense_consistent,
            observed_drop_rate,
            param_fingerprint,
        })
    }

    /// Run THIS process's rank over the TCP fabric (`--fabric tcp`).
    /// Returns `Some(report)` on rank 0 after the end-of-run gathers and
    /// the shutdown handshake; `None` on every other rank.
    pub fn run_net(cfg: &DistRunConfig, net: &NetOpts) -> Result<Option<NetRunReport>> {
        let manifest = DistManifest::load(&cfg.artifact_dir)?;
        crate::ensure!(
            net.world == manifest.ranks,
            "artifact exported for {} ranks, requested world {}",
            manifest.ranks,
            net.world
        );
        crate::ensure!(
            cfg.n_ranks == net.world,
            "--ranks {} disagrees with --world {}",
            cfg.n_ranks,
            net.world
        );
        crate::ensure!(cfg.overlap_chunks >= 1, "overlap_chunks must be >= 1");
        crate::ensure!(
            cfg.overlap_chunks == 1 || manifest.synthetic_seed.is_some(),
            "overlap_chunks > 1 requires the synthetic manifest: the XLA stage \
             artifacts are compiled for full-capacity shapes only"
        );
        // auto thread budget assumes the common tcp-local case of `world`
        // sibling processes on this host; cross-host launches should pass
        // --threads explicitly
        let per_rank_threads = match resolve_threads_explicit(cfg.threads)? {
            Some(explicit) => explicit,
            None => {
                (std::thread::available_parallelism().map_or(1, |p| p.get()) / net.world).max(1)
            }
        };
        let seq_cutoff = resolve_seq_cutoff()?;
        init_kernel_kind()?;
        let mut ncfg = NetConfig::new(net.rank, net.world, net.coord.clone());
        ncfg.io_timeout_ms = net.timeout_ms;
        ncfg.connect_retries = net.retries;
        ncfg.retry_backoff_ms = net.backoff_ms;
        ncfg.cluster = cfg.cluster;
        let fabric = Arc::new(Fabric::Net(NetFabric::connect(&ncfg)?));
        let task = ClusterTask::new(manifest.d_in, manifest.n_classes, net.world, cfg.seed);
        let (losses, _walls, fp, efp, drop_rate) = run_rank(
            net.rank,
            fabric.clone(),
            manifest,
            cfg,
            per_rank_threads,
            seq_cutoff,
            &task,
            net.die_at_step,
        )?;
        // end-of-run collection to rank 0, off the accounted books: the
        // dense fingerprints (consistency check), the resident experts
        // (full-model hash), and each rank's local stats blob
        let netfab = fabric.as_net().expect("run_net built a net fabric");
        let dense = netfab.gather_bytes(f32s_le(&fp))?;
        let experts = netfab.gather_bytes(f32s_le(&efp))?;
        let stats = netfab.gather_bytes(netfab.stats().to_le_bytes())?;
        netfab.shutdown()?;
        let (Some(dense), Some(experts), Some(stats)) = (dense, experts, stats) else {
            return Ok(None);
        };
        let dense_consistent = dense.windows(2).all(|w| w[0] == w[1]);
        // the thread-mode fingerprint order: rank-0 dense parameters,
        // then every rank's resident expert
        let mut all = dense[0].clone();
        for e in &experts {
            all.extend_from_slice(e);
        }
        let per_rank: Vec<FabricStats> =
            stats.iter().map(|b| FabricStats::from_le_bytes(b)).collect::<Result<_>>()?;
        Ok(Some(NetRunReport {
            losses,
            fabric: FabricStats::merge_ranks(&per_rank),
            dense_consistent,
            fingerprint_hash: fnv1a64(&all),
            observed_drop_rate: drop_rate,
        }))
    }

    /// The `--fabric tcp-local` launcher: spawn `net.world` child
    /// `repro dist --fabric tcp` processes over loopback and parse the
    /// rank-0 result line. `exe` is the repro binary (tests pass
    /// `env!("CARGO_BIN_EXE_repro")`; the CLI passes its own path). With
    /// `net.die_at_step` set, the LAST rank gets the kill switch -- the
    /// launcher then reports the survivors' typed errors.
    pub fn run_tcp_local(cfg: &DistRunConfig, net: &NetOpts, exe: &str) -> Result<NetRunReport> {
        let world = net.world;
        crate::ensure!(world >= 1, "tcp-local world must be >= 1");
        // probe a free loopback port and hand it to the children; rank 0
        // rebinds it (NetFabric's bind retry covers the tiny race)
        let coord = {
            let l = TcpListener::bind("127.0.0.1:0").context("probing a loopback port")?;
            l.local_addr().context("probe addr")?.to_string()
        };
        let mut children = Vec::new();
        for rank in 0..world {
            let mut c = Command::new(exe);
            c.arg("dist")
                .args(["--fabric", "tcp"])
                .args(["--rank", &rank.to_string()])
                .args(["--world", &world.to_string()])
                .args(["--coord", &coord])
                .args(["--artifacts", &cfg.artifact_dir])
                .args(["--ranks", &world.to_string()])
                .args(["--steps", &cfg.steps.to_string()])
                .args(["--seed", &cfg.seed.to_string()])
                .args(["--lr", &format!("{}", cfg.lr)])
                .args(["--threads", &cfg.threads.to_string()])
                .args(["--policy", &policy_flag(cfg.policy)])
                .args(["--overlap-chunks", &cfg.overlap_chunks.to_string()])
                .args(["--net-timeout-ms", &net.timeout_ms.to_string()])
                .args(["--net-retries", &net.retries.to_string()])
                .args(["--net-backoff-ms", &net.backoff_ms.to_string()]);
            match cfg.router {
                moe::Router::Top1 => {
                    c.args(["--router", "top1"]);
                }
                moe::Router::TopK { k } => {
                    c.args(["--router", "topk", "--topk", &k.to_string()]);
                }
                moe::Router::Adaptive { thresh, k_max } => {
                    c.args(["--router", "adaptive", "--topk", &k_max.to_string()]);
                    c.args(["--adaptive-thresh", &format!("{thresh}")]);
                }
            }
            if rank == world - 1 {
                if let Some(s) = net.die_at_step {
                    c.args(["--net-die-at-step", &s.to_string()]);
                }
            }
            c.stdout(if rank == 0 { Stdio::piped() } else { Stdio::null() });
            c.stderr(Stdio::inherit());
            let child = c
                .spawn()
                .with_context(|| format!("spawning tcp-local rank {rank} ({exe})"))?;
            children.push(child);
        }
        let mut rank0_out = String::new();
        let mut failures = Vec::new();
        for (rank, mut child) in children.into_iter().enumerate() {
            if rank == 0 {
                if let Some(mut out) = child.stdout.take() {
                    use std::io::Read as _;
                    let _ = out.read_to_string(&mut rank0_out);
                }
            }
            let status =
                child.wait().with_context(|| format!("waiting on tcp-local rank {rank}"))?;
            if !status.success() {
                failures.push(format!("rank {rank} exited with {status}"));
            }
        }
        crate::ensure!(
            failures.is_empty(),
            "tcp-local ranks failed: {}",
            failures.join("; ")
        );
        NetRunReport::parse_result_line(&rank0_out)
    }
}

#[cfg(test)]
mod tests {
    // Integration tests live in rust/tests/distributed.rs (they need the
    // AOT artifacts); unit coverage for the pieces is in moe/optim/task.
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = DistRunConfig::default();
        assert_eq!(c.n_ranks, 4);
        assert!(c.steps > 0);
    }

    #[test]
    fn missing_artifacts_is_clean_error() {
        let cfg = DistRunConfig { artifact_dir: "/nonexistent".into(), ..Default::default() };
        let err = DistEngine::run(&cfg).unwrap_err().to_string();
        assert!(err.contains("manifest"), "got: {err}");
    }

    #[test]
    fn chunk_bounds_cover_the_slot_space_contiguously() {
        for (cap, c) in [(7usize, 3usize), (8, 4), (5, 9), (1, 1), (256, 2), (6, 1)] {
            let b = chunk_bounds(cap, c);
            assert_eq!(b.len(), c.clamp(1, cap), "cap {cap} chunks {c}");
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, cap);
            assert!(b.windows(2).all(|w| w[0].1 == w[1].0), "gaps: {b:?}");
            let sizes: Vec<usize> = b.iter().map(|&(lo, hi)| hi - lo).collect();
            let (mn, mx) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced chunks for {cap}/{c}: {sizes:?}");
        }
    }

    #[test]
    fn result_line_round_trips_bit_exact() {
        let report = NetRunReport {
            losses: vec![1.25, -0.5, f32::MIN_POSITIVE, 3.0e-7],
            fabric: FabricStats {
                a2a_ops: 12,
                a2a_bytes: 34_567,
                counts_ops: 24,
                counts_bytes: 288,
                allreduce_ops: 120,
                allreduce_bytes: 99_000,
                broadcast_ops: 30,
                broadcast_bytes: 30,
                modeled_time: 0.012_345,
                modeled_compute: 3.5e-4,
                overlapped_ticks: 1.0 / 3.0,
                wall_a2a_nanos: 1_234_567,
                wall_bytes: 40_000,
            },
            dense_consistent: true,
            fingerprint_hash: 0xdead_beef_cafe_f00d,
            observed_drop_rate: 0.3,
        };
        let line = report.result_line();
        let back = NetRunReport::parse_result_line(&format!("noise\n{line}\nmore"))
            .expect("round trip");
        assert_eq!(back, report);
        // empty-loss runs still carry a parseable line
        let empty = NetRunReport { losses: Vec::new(), ..report };
        assert_eq!(NetRunReport::parse_result_line(&empty.result_line()).unwrap(), empty);
        let err = NetRunReport::parse_result_line("no result here").unwrap_err().to_string();
        assert!(err.contains("GDNET_RESULT"), "got: {err}");
    }

    #[test]
    fn policy_flag_round_trips_through_parse() {
        for p in [
            Policy::Baseline,
            Policy::GateDrop { p: 0.3 },
            Policy::GateDrop { p: 0.25 },
            Policy::GateExpertDrop { p: 0.4 },
            Policy::HashLayer,
            Policy::NoAllToAll,
        ] {
            let flag = policy_flag(p);
            assert_eq!(Policy::parse(&flag), Some(p), "flag {flag:?} must parse back");
        }
    }

    #[test]
    fn zero_overlap_chunks_is_rejected() {
        let cfg = DistRunConfig {
            artifact_dir: "synthetic".into(),
            overlap_chunks: 0,
            ..Default::default()
        };
        let err = DistEngine::run(&cfg).unwrap_err().to_string();
        assert!(err.contains("overlap_chunks"), "got: {err}");
    }
}
