//! Worker threads + the full distributed training step.
//!
//! Forward/backward dataflow per rank (see dist_stages.py for the stage
//! algebra and mod.rs for the step diagram):
//!
//!   s1_fwd -> route (gated / hash / LOCAL on dropped steps)
//!          -> [all-to-all]            (skipped when the decision drops)
//!          -> expert_fwd              (skipped on Gate-Expert-Drop)
//!          -> [all-to-all back] -> y = h + gate*ye
//!          -> head_loss_bwd -> dy
//!          -> [all-to-all dye] -> expert_bwd -> [all-to-all dxe]
//!          -> s1_bwd -> all_reduce(dense grads) -> host Adam
//!
//! Expert parameters never leave their rank (expert parallelism); dense
//! parameters stay bit-identical across ranks because they see identical
//! all-reduced gradients -- asserted after every run.

use std::sync::Arc;
use std::time::Instant;

use crate::util::error::Result;

use crate::collective::{Collective, FabricStats, ThreadFabric};
use crate::coordinator::{Decision, DistCoordinator, Policy};
use crate::moe;
use crate::runtime::tensor::{resolve_seq_cutoff, resolve_threads_explicit, ThreadPool};
use crate::topology::Topology;
use crate::util::rng::Rng;

use super::optim::Adam;
use super::stages::{lit1, lit1_i32, lit2, DistManifest, StageRunner};
use super::task::ClusterTask;

#[derive(Debug, Clone)]
pub struct DistRunConfig {
    pub artifact_dir: String,
    pub n_ranks: usize,
    pub steps: u64,
    pub policy: Policy,
    pub seed: u64,
    pub lr: f32,
    /// Worker threads PER RANK for the pure-Rust stage math (each rank
    /// attaches a persistent `tensor::ThreadPool` to its `StageRunner`).
    /// `0` = auto: divide the machine's available parallelism across the
    /// ranks -- which are already `ThreadFabric` threads -- so the sim
    /// never oversubscribes by default. An explicit value (CLI
    /// `--threads`, config `"threads"`, or the `GD_THREADS` env override)
    /// is taken as the per-rank count verbatim. Thread count never
    /// changes results: the pooled stage kernels are bit-identical to
    /// the sequential ones.
    pub threads: usize,
    /// Router for routed (non-dropped, non-hash) steps. `Top1` (the
    /// default) runs the seed's `moe::top1` scan verbatim; `TopK` /
    /// `Adaptive` send each token to multiple experts over the same
    /// two-phase wire (the counts phase already sizes variable fan-out).
    pub router: moe::Router,
}

impl Default for DistRunConfig {
    fn default() -> Self {
        // Without the XLA stage artifacts compiled in, default to the
        // deterministic synthetic dist model (pure-Rust stage runner).
        let artifact_dir = if cfg!(feature = "backend-xla") {
            "artifacts/dist"
        } else {
            "synthetic"
        };
        DistRunConfig {
            artifact_dir: artifact_dir.into(),
            n_ranks: 4,
            steps: 30,
            policy: Policy::Baseline,
            seed: 7,
            lr: 2e-3,
            threads: 0,
            router: moe::Router::Top1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DistRunResult {
    /// Rank-mean loss per step.
    pub losses: Vec<f32>,
    pub fabric: FabricStats,
    pub wall_secs: f64,
    /// (decision.drop, rank-0 measured step seconds) per step.
    pub step_wall: Vec<(bool, f64)>,
    /// Dense parameters bit-identical across ranks at the end?
    pub dense_consistent: bool,
    pub observed_drop_rate: f64,
}

struct WorkerState {
    rank: usize,
    topo: Topology,
    runner: StageRunner,
    router: moe::Router,
    // dense (replicated)
    w_in: Vec<f32>,
    b_in: Vec<f32>,
    wr: Vec<f32>,
    w_out: Vec<f32>,
    // resident expert
    w1: Vec<f32>,
    w2: Vec<f32>,
    // host optimizers
    o_win: Adam,
    o_bin: Adam,
    o_wr: Adam,
    o_wout: Adam,
    o_w1: Adam,
    o_w2: Adam,
}

impl WorkerState {
    fn new(
        rank: usize,
        m: DistManifest,
        lr: f32,
        threads: usize,
        seq_cutoff: usize,
        router: moe::Router,
    ) -> Result<WorkerState> {
        let topo = Topology::new(m.ranks, m.ranks); // one expert per rank
        let w_in = m.load_init("w_in")?;
        let b_in = m.load_init("b_in")?;
        let wr = m.load_init("wr")?;
        let w_out = m.load_init("w_out")?;
        let w1 = m.load_init(&format!("expert{rank}_w1"))?;
        let w2 = m.load_init(&format!("expert{rank}_w2"))?;
        let mut runner = StageRunner::new(m)?;
        if threads > 1 {
            // this rank's slice of the machine: persistent workers under
            // the ThreadFabric rank thread, bit-neutral by the kernel
            // parity contract (cutoff resolved once by the engine, so a
            // bad GD_SEQ_CUTOFF errors at launch, not as a rank panic)
            runner.set_thread_pool(ThreadPool::with_cutoff(threads, seq_cutoff));
        }
        Ok(WorkerState {
            rank,
            topo,
            router,
            o_win: Adam::new(w_in.len(), lr),
            o_bin: Adam::new(b_in.len(), lr),
            o_wr: Adam::new(wr.len(), lr),
            o_wout: Adam::new(w_out.len(), lr),
            o_w1: Adam::new(w1.len(), lr),
            o_w2: Adam::new(w2.len(), lr),
            w_in,
            b_in,
            wr,
            w_out,
            w1,
            w2,
            runner,
        })
    }

    /// One full training step; returns this rank's loss.
    fn step(
        &mut self,
        fabric: &ThreadFabric,
        decision: Decision,
        x: &[f32],
        labels: &[i32],
        token_ids: &[u32],
    ) -> Result<f32> {
        let m = &self.runner.manifest;
        let (din, d, t, r) = (m.d_in, m.d_model, m.tokens_per_rank, m.ranks);
        // Expert buffer rows: the per-token share times the router's
        // fan-out bound on routed steps (x1 under any k=1 routing --
        // identical to the seed's `cap = t`). Dropped/hashed steps force
        // one slot per token, so their capacity stays the seed's
        // regardless of the configured router.
        let kmax = if decision.drop || decision.hash_route { 1 } else { self.router.max_k() };
        let cap = t * kmax;
        let stride = moe::HEADER + d;

        // ---- stage 1 forward -------------------------------------------------
        let out = self.runner.run(
            "s1_fwd",
            &[
                lit2(&self.w_in, din, d)?,
                lit1(&self.b_in),
                lit2(&self.wr, d, r)?,
                lit2(x, t, din)?,
            ],
        )?;
        let (h, probs) = (&out[0], &out[1]);

        // ---- routing ---------------------------------------------------------
        // CSR assignment: dropped/hashed steps force one expert per token
        // (offsets 0..=t, the seed layout); routed steps go through the
        // configured router (Top1 runs the seed's `moe::top1` scan).
        let assign: moe::RouteAssign = if decision.drop {
            // Gating Dropout: every token to the rank's own expert.
            let e: Vec<usize> = (0..t).map(|_| self.rank).collect();
            let g: Vec<f32> = (0..t).map(|i| moe::gate_of(probs, r, i, self.rank)).collect();
            moe::RouteAssign::from_single(e, g)
        } else if decision.hash_route {
            // Hash-Layer routing hashes the token's VOCAB id (the
            // `model._hash_ids` convention), not its batch position.
            let (e, g) = moe::hash_route(token_ids, probs, r);
            moe::RouteAssign::from_single(e, g)
        } else {
            self.router.route(probs, t, r)
        };

        // ---- dispatch (+all-to-all unless dropped) ---------------------------
        let (xe, admitted) = if decision.drop {
            if decision.expert_skip {
                (Vec::new(), Vec::new())
            } else {
                // local-only: xe = h rows in token order, slot = token idx
                let admitted: Vec<moe::Admitted> = (0..t)
                    .map(|i| moe::Admitted {
                        src_rank: self.rank,
                        src_idx: i,
                        gate: assign.gates[i],
                        slot: i,
                        local_expert: 0,
                    })
                    .collect();
                (h.clone(), admitted)
            }
        } else {
            // two-phase flat dispatch: counts first, then exactly-sized
            // contiguous buffers through the row-counted all-to-all (one
            // wire row per (token, slot) -- variable fan-out rides the
            // same counts phase).
            let counts = self.topo.owner_counts(&assign.experts);
            let recv_rows = fabric.all_to_all_counts(self.rank, &counts);
            let packed = moe::route_pack_k(&self.topo, h, d, &assign, &counts);
            let arrivals =
                fabric.all_to_all_rows(self.rank, packed, &counts, &recv_rows, stride);
            moe::route_admit(self.rank, &self.topo, &arrivals, d, cap)
        };

        // ---- expert forward (skipped on Gate-Expert-Drop) --------------------
        let ye: Option<Vec<f32>> = if decision.runs_expert() {
            let out = self.runner.run(
                "expert_fwd",
                &[
                    lit2(&self.w1, d, m.d_ff)?,
                    lit2(&self.w2, m.d_ff, d)?,
                    lit2(&xe, cap, d)?,
                ],
            )?;
            Some(out.into_iter().next().unwrap())
        } else {
            None
        };

        // ---- combine (+return all-to-all unless dropped) ---------------------
        // admitted tokens per home rank: shared by the return leg and both
        // backward wire legs (they all ride the admission edges).
        let ret_counts: Vec<usize> = if decision.drop {
            Vec::new()
        } else {
            moe::return_counts(&self.topo, &admitted)
        };
        // own (token, slot) rows admitted per owner rank: the return-leg
        // counts phase delivers exactly this, and both backward wire legs
        // reuse it (empty on dropped / expert-skipped steps, where no
        // wire runs).
        let mut surviving: Vec<usize> = Vec::new();
        // ret: weighted combine + per-arrival-row records on the home rank.
        let ret: moe::ReturnedK = match (&ye, decision.drop) {
            (None, _) => moe::ReturnedK {
                combined: vec![0.0; t * d],
                raw: Vec::new(),
                rows: Vec::new(),
            },
            (Some(ye), true) => {
                // local: token i <-> slot i, one row per token
                let mut out = moe::ReturnedK {
                    combined: vec![0.0; t * d],
                    raw: ye.clone(),
                    rows: (0..t)
                        .map(|i| moe::RetRow {
                            token: i,
                            owner: self.rank,
                            slot: i,
                            gate: assign.gates[i],
                        })
                        .collect(),
                };
                for i in 0..t {
                    for j in 0..d {
                        out.combined[i * d + j] = assign.gates[i] * ye[i * d + j];
                    }
                }
                out
            }
            (Some(ye), false) => {
                // counts phase again: the home rank cannot predict how
                // many of its rows survived capacity admission here.
                let recv_rows = fabric.all_to_all_counts(self.rank, &ret_counts);
                let back = moe::return_pack(&self.topo, &admitted, ye, d, &ret_counts);
                let arrivals =
                    fabric.all_to_all_rows(self.rank, back, &ret_counts, &recv_rows, stride);
                surviving = recv_rows;
                moe::return_unpack_k(&arrivals, t, d)
            }
        };
        let mut y = vec![0f32; t * d];
        for i in 0..t * d {
            y[i] = h[i] + ret.combined[i];
        }

        // ---- head + loss + dy -------------------------------------------------
        let out = self.runner.run(
            "head_loss_bwd",
            &[
                lit2(&self.w_out, d, m.n_classes)?,
                lit2(&y, t, d)?,
                lit1_i32(labels),
            ],
        )?;
        let loss = out[0][0];
        let dy = &out[1];
        let dw_out = out[2].clone();

        // ---- backward through combine / expert / dispatch --------------------
        let mut dh: Vec<f32> = dy.clone(); // residual path
        let mut dprobs = vec![0f32; t * r];
        let (dw1, dw2): (Vec<f32>, Vec<f32>) = if decision.runs_expert() {
            // cotangents for expert outputs, one per returned (token, slot)
            // row; scatter each onto its CSR slot (one expert per rank, so
            // a (token, owner) pair names at most one slot) and push the
            // gate gradients through the router VJP -- the raw-prob gate
            // at k=1 (the seed's scatter), renormalized-softmax at k>=2.
            let mut dgates = vec![0f32; assign.n_slots()];
            for (ri, row) in ret.rows.iter().enumerate() {
                let mut acc = 0f32;
                for j in 0..d {
                    acc += dy[row.token * d + j] * ret.raw[ri * d + j];
                }
                for s in assign.range(row.token) {
                    if self.topo.owner_of(assign.experts[s]) == row.owner {
                        dgates[s] = acc;
                        break;
                    }
                }
            }
            moe::router_vjp(&assign, probs, &dgates, r, &mut dprobs);
            // Both backward wire legs ride the admission edges, so no
            // counts phase goes on the wire: this rank *receives* one dye
            // row / *sends* one dxe row per token it admitted
            // (`ret_counts`), and *sends* one dye row / *receives* one
            // dxe row per own token that survived admission (`surviving`,
            // already delivered by the return-leg counts phase).
            // dye rows to expert ranks
            let dye_buf: Vec<f32> = if decision.drop {
                // local: slot i = token i
                let mut buf = vec![0f32; cap * d];
                for i in 0..t {
                    for j in 0..d {
                        buf[i * d + j] = assign.gates[i] * dy[i * d + j];
                    }
                }
                buf
            } else {
                // ship [slot, src_idx, gate, gate*dy_row] to the expert
                // owner, one message per surviving returned row (rows
                // arrive owner-major, token-ascending, so per-destination
                // packing order matches the seed's token scan at k=1)
                let mut msgs: Vec<Vec<f32>> = surviving
                    .iter()
                    .map(|&c| Vec::with_capacity(c * stride))
                    .collect();
                for row in &ret.rows {
                    let msg = &mut msgs[row.owner];
                    msg.extend_from_slice(&[row.slot as f32, row.token as f32, row.gate]);
                    msg.extend(
                        dy[row.token * d..(row.token + 1) * d].iter().map(|&v| row.gate * v),
                    );
                }
                let arrivals =
                    fabric.all_to_all_rows(self.rank, msgs, &surviving, &ret_counts, stride);
                let mut buf = vec![0f32; cap * d];
                for msg in &arrivals {
                    for tok in msg.chunks_exact(stride) {
                        let slot = tok[0] as usize;
                        buf[slot * d..(slot + 1) * d].copy_from_slice(&tok[moe::HEADER..]);
                    }
                }
                buf
            };
            let out = self.runner.run(
                "expert_bwd",
                &[
                    lit2(&self.w1, d, m.d_ff)?,
                    lit2(&self.w2, m.d_ff, d)?,
                    lit2(&xe, cap, d)?,
                    lit2(&dye_buf, cap, d)?,
                ],
            )?;
            let dxe = &out[0];
            let dw1 = out[1].clone();
            let dw2 = out[2].clone();
            // route dxe rows back to token home ranks -> dh += dxe
            if decision.drop {
                for i in 0..t * d {
                    dh[i] += dxe[i];
                }
            } else {
                // dxe retraces the admission edges in reverse: sender
                // sizes from `ret_counts`, home ranks expect `surviving`
                let mut msgs: Vec<Vec<f32>> = ret_counts
                    .iter()
                    .map(|&c| Vec::with_capacity(c * stride))
                    .collect();
                for a in &admitted {
                    let msg = &mut msgs[a.src_rank];
                    msg.extend_from_slice(&[a.slot as f32, a.src_idx as f32, a.gate]);
                    msg.extend_from_slice(&dxe[a.slot * d..(a.slot + 1) * d]);
                }
                let arrivals =
                    fabric.all_to_all_rows(self.rank, msgs, &ret_counts, &surviving, stride);
                for msg in &arrivals {
                    for tok in msg.chunks_exact(stride) {
                        let i = tok[1] as usize;
                        for j in 0..d {
                            dh[i * d + j] += tok[moe::HEADER + j];
                        }
                    }
                }
            }
            (dw1, dw2)
        } else {
            (vec![0f32; self.w1.len()], vec![0f32; self.w2.len()])
        };

        // ---- stage-1 backward -------------------------------------------------
        let out = self.runner.run(
            "s1_bwd",
            &[
                lit2(&self.w_in, din, d)?,
                lit1(&self.b_in),
                lit2(&self.wr, d, r)?,
                lit2(x, t, din)?,
                lit2(&dh, t, d)?,
                lit2(&dprobs, t, r)?,
            ],
        )?;
        let (mut dw_in, mut db_in, mut dwr) = (out[0].clone(), out[1].clone(), out[2].clone());

        // ---- dense all-reduce + host Adam -------------------------------------
        let mut dw_out = dw_out;
        fabric.all_reduce_sum(self.rank, &mut dw_in);
        fabric.all_reduce_sum(self.rank, &mut db_in);
        fabric.all_reduce_sum(self.rank, &mut dwr);
        fabric.all_reduce_sum(self.rank, &mut dw_out);
        let scale = 1.0 / r as f32;
        for g in [&mut dw_in, &mut db_in, &mut dwr, &mut dw_out] {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
        self.o_win.step(&mut self.w_in, &dw_in);
        self.o_bin.step(&mut self.b_in, &db_in);
        self.o_wr.step(&mut self.wr, &dwr);
        self.o_wout.step(&mut self.w_out, &dw_out);
        if decision.runs_expert() {
            self.o_w1.step(&mut self.w1, &dw1);
            self.o_w2.step(&mut self.w2, &dw2);
        }
        Ok(loss)
    }
}

pub struct DistEngine;

impl DistEngine {
    /// Run `cfg.steps` of distributed training; returns losses + fabric
    /// accounting + per-step wallclock split by decision.
    pub fn run(cfg: &DistRunConfig) -> Result<DistRunResult> {
        let manifest = DistManifest::load(&cfg.artifact_dir)?;
        crate::ensure!(
            cfg.n_ranks == manifest.ranks,
            "artifact exported for {} ranks, requested {}",
            manifest.ranks,
            cfg.n_ranks
        );
        let n = manifest.ranks;
        // Per-rank thread budget for the stage math. Explicit requests
        // (CLI --threads / config "threads" / GD_THREADS env) are taken
        // as workers PER RANK; auto (0) divides the machine's available
        // parallelism across the rank threads so the default never
        // oversubscribes. Either way the bits cannot move -- the pooled
        // stage kernels are bit-identical to the sequential ones.
        let per_rank_threads = match resolve_threads_explicit(cfg.threads)? {
            Some(explicit) => explicit,
            None => (std::thread::available_parallelism().map_or(1, |p| p.get()) / n).max(1),
        };
        // resolve the cutoff once here so a garbage GD_SEQ_CUTOFF is a
        // clean launch error, not a panic inside every rank thread
        let seq_cutoff = resolve_seq_cutoff()?;
        let fabric = Arc::new(ThreadFabric::new(n));
        let task = Arc::new(ClusterTask::new(
            manifest.d_in,
            manifest.n_classes,
            n,
            cfg.seed,
        ));
        let started = Instant::now();
        let mut handles = Vec::new();
        for rank in 0..n {
            let fabric = fabric.clone();
            let task = task.clone();
            let manifest = manifest.clone();
            let cfg = cfg.clone();
            type WorkerOut = (Vec<f32>, Vec<(bool, f64)>, Vec<f32>, f64);
            handles.push(std::thread::spawn(move || -> Result<WorkerOut> {
                let mut w = WorkerState::new(
                    rank,
                    manifest,
                    cfg.lr,
                    per_rank_threads,
                    seq_cutoff,
                    cfg.router,
                )?;
                let mut coord = DistCoordinator::new(rank, fabric.clone(), cfg.policy, cfg.seed);
                let mut rng = Rng::new(cfg.seed).fork(100 + rank as u64);
                let mut losses = Vec::new();
                let mut walls = Vec::new();
                let t = w.runner.manifest.tokens_per_rank;
                for step in 0..cfg.steps {
                    let decision = coord.decide(step);
                    let (x, labels, token_ids) = task.sample(rank, t, &mut rng);
                    let t0 = Instant::now();
                    let mut loss = w.step(&fabric, decision, &x, &labels, &token_ids)?;
                    walls.push((decision.drop, t0.elapsed().as_secs_f64()));
                    // rank-mean loss for reporting: diagnostics only, so it
                    // must stay OUT of the training-communication stats
                    let mut lbuf = vec![loss];
                    fabric.all_reduce_sum_unaccounted(rank, &mut lbuf);
                    loss = lbuf[0] / cfg.n_ranks as f32;
                    losses.push(loss);
                }
                let drop_rate = coord
                    .audit_log()
                    .iter()
                    .filter(|&&b| crate::coordinator::Decision::decode(b).drop)
                    .count() as f64
                    / cfg.steps.max(1) as f64;
                // dense-param fingerprint for the consistency check
                let mut fp = w.w_in.clone();
                fp.extend_from_slice(&w.wr);
                fp.extend_from_slice(&w.w_out);
                Ok((losses, walls, fp, drop_rate))
            }));
        }
        let mut all: Vec<(Vec<f32>, Vec<(bool, f64)>, Vec<f32>, f64)> = Vec::new();
        for h in handles {
            all.push(h.join().map_err(|_| crate::err!("worker panicked"))??);
        }
        let dense_consistent = all.windows(2).all(|w| w[0].2 == w[1].2);
        let losses = all[0].0.clone();
        let step_wall = all[0].1.clone();
        let observed_drop_rate = all[0].3;
        Ok(DistRunResult {
            losses,
            fabric: fabric.stats(),
            wall_secs: started.elapsed().as_secs_f64(),
            step_wall,
            dense_consistent,
            observed_drop_rate,
        })
    }
}

#[cfg(test)]
mod tests {
    // Integration tests live in rust/tests/distributed.rs (they need the
    // AOT artifacts); unit coverage for the pieces is in moe/optim/task.
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = DistRunConfig::default();
        assert_eq!(c.n_ranks, 4);
        assert!(c.steps > 0);
    }

    #[test]
    fn missing_artifacts_is_clean_error() {
        let cfg = DistRunConfig { artifact_dir: "/nonexistent".into(), ..Default::default() };
        let err = DistEngine::run(&cfg).unwrap_err().to_string();
        assert!(err.contains("manifest"), "got: {err}");
    }
}
