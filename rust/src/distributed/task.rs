//! Synthetic workload for the distributed engine: a token classification
//! task with per-rank language clusters.
//!
//! Each rank's tokens are drawn around that rank's language centroid (so
//! experts *can* specialise by language, and gated routing has something
//! to learn), and the label is a fixed hidden teacher `argmax(W_t x + b_l)`
//! with a per-language bias -- learnable, deterministic ground truth.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClusterTask {
    pub d_in: usize,
    pub n_classes: usize,
    pub n_langs: usize,
    /// Vocabulary size for the per-token ids (Hash-Layer routing hashes
    /// these, matching `model._hash_ids` on the single-process path).
    pub vocab: usize,
    centroids: Vec<f32>, // [n_langs, d_in]
    teacher_w: Vec<f32>, // [d_in, n_classes]
    teacher_b: Vec<f32>, // [n_langs, n_classes]
}

impl ClusterTask {
    pub fn new(d_in: usize, n_classes: usize, n_langs: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork(0x7A5C);
        let centroids = (0..n_langs * d_in).map(|_| rng.normal() as f32 * 0.8).collect();
        let teacher_w = (0..d_in * n_classes).map(|_| rng.normal() as f32).collect();
        let teacher_b = (0..n_langs * n_classes).map(|_| rng.normal() as f32 * 0.5).collect();
        ClusterTask { d_in, n_classes, n_langs, vocab: 32_768, centroids, teacher_w, teacher_b }
    }

    /// Sample `t` tokens for `rank` (language = rank % n_langs).
    /// Returns (x row-major [t, d_in], labels [t], vocab ids [t]).
    ///
    /// Ids ride a *forked* stream (`fork` reads, never advances, the
    /// caller's rng), so the x/label streams are bit-identical to what
    /// they were before ids existed -- fixed-seed runs reproduce the seed
    /// losses exactly on every policy that ignores ids.
    pub fn sample(&self, rank: usize, t: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>, Vec<u32>) {
        let mut id_rng = rng.fork(0x1D5);
        let lang = rank % self.n_langs;
        let mut x = Vec::with_capacity(t * self.d_in);
        let mut labels = Vec::with_capacity(t);
        for _ in 0..t {
            let start = x.len();
            for j in 0..self.d_in {
                x.push(self.centroids[lang * self.d_in + j] + rng.normal() as f32);
            }
            labels.push(self.label_of(&x[start..], lang));
        }
        let ids: Vec<u32> = (0..t).map(|_| id_rng.below(self.vocab as u64) as u32).collect();
        (x, labels, ids)
    }

    fn label_of(&self, row: &[f32], lang: usize) -> i32 {
        let mut best = (0usize, f32::NEG_INFINITY);
        for k in 0..self.n_classes {
            let mut s = self.teacher_b[lang * self.n_classes + k];
            for j in 0..self.d_in {
                s += row[j] * self.teacher_w[j * self.n_classes + k];
            }
            if s > best.1 {
                best = (k, s);
            }
        }
        best.0 as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_deterministic_and_in_range() {
        let task = ClusterTask::new(8, 4, 2, 3);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let (x1, l1, i1) = task.sample(0, 32, &mut r1);
        let (x2, l2, i2) = task.sample(0, 32, &mut r2);
        assert_eq!(x1, x2);
        assert_eq!(l1, l2);
        assert_eq!(i1, i2);
        assert!(l1.iter().all(|&l| (0..4).contains(&l)));
        assert!(i1.iter().all(|&id| (id as usize) < task.vocab));
    }

    #[test]
    fn ranks_have_distinct_clusters() {
        let task = ClusterTask::new(8, 4, 4, 3);
        let mut rng = Rng::new(7);
        let (x0, _, _) = task.sample(0, 64, &mut rng);
        let (x1, _, _) = task.sample(1, 64, &mut rng);
        let mean = |x: &[f32]| x.iter().sum::<f32>() / x.len() as f32;
        // different centroids shift the means; extremely unlikely to match
        assert!((mean(&x0) - mean(&x1)).abs() > 1e-3);
    }

    #[test]
    fn labels_not_constant() {
        let task = ClusterTask::new(8, 8, 2, 11);
        let mut rng = Rng::new(1);
        let (_, labels, _) = task.sample(0, 128, &mut rng);
        let first = labels[0];
        assert!(labels.iter().any(|&l| l != first), "teacher degenerate");
    }

    #[test]
    fn ids_do_not_perturb_the_x_stream() {
        // the id stream is forked off, so consecutive samples from one rng
        // produce the same x/labels that a two-sample sequence always did;
        // in particular sampling twice gives different x (rng advanced by
        // x/labels only, deterministically).
        let task = ClusterTask::new(8, 4, 2, 3);
        let mut rng = Rng::new(5);
        let (xa, _, ia) = task.sample(0, 16, &mut rng);
        let (xb, _, ib) = task.sample(0, 16, &mut rng);
        assert_ne!(xa, xb, "rng must advance across samples");
        assert_ne!(ia, ib, "id stream must advance with the rng state");
    }
}
