//! Tiny benchmark harness (offline crate set has no criterion).
//!
//! `cargo bench` binaries use `harness = false` and drive this: warmup,
//! N timed iterations, median / p10 / p90 reporting, and table-style
//! output helpers so every paper table/figure bench prints rows in the
//! paper's own format.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Stats { median_ns: q(0.5), p10_ns: q(0.1), p90_ns: q(0.9), iters }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<44} median {:>10}   p10 {:>10}   p90 {:>10}   ({} iters)",
        fmt_ns(s.median_ns),
        fmt_ns(s.p10_ns),
        fmt_ns(s.p90_ns),
        s.iters
    );
}

/// Simple aligned table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// One old-vs-new throughput comparison over a shared work count: prints
/// both variants as tokens/sec plus the speedup, in the same shape as
/// the microbench `[seed]`/`[flat]` rows. The serving bench
/// (`repro bench-serve`) reports batched-vs-sequential decode through
/// this; returns the speedup so smoke gates can assert on it.
pub fn report_tps_speedup(
    name: &str,
    work_tokens: u64,
    base_label: &str,
    base_secs: f64,
    new_label: &str,
    new_secs: f64,
) -> f64 {
    let tps = |secs: f64| work_tokens as f64 / secs.max(1e-12);
    let speedup = base_secs / new_secs.max(1e-12);
    println!(
        "{name:<44} [{base_label}] {:>10}  ({})",
        fmt_tps(tps(base_secs)),
        fmt_ns(base_secs * 1e9),
    );
    println!(
        "{name:<44} [{new_label}] {:>10}  ({})",
        fmt_tps(tps(new_secs)),
        fmt_ns(new_secs * 1e9),
    );
    println!("{name:<44} speedup {speedup:.2}x");
    speedup
}

/// One machine-readable benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchEntry {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

impl BenchEntry {
    pub fn new(name: impl Into<String>, value: f64, unit: impl Into<String>) -> BenchEntry {
        BenchEntry { name: name.into(), value, unit: unit.into() }
    }
}

/// Write benchmark entries as a `BENCH_*.json` artifact (schema
/// `gd-bench-v1`) so sweeps and CI can diff runs without scraping the
/// human-readable tables. The microbench sections emit through this;
/// `GD_BENCH_DIR` picks the output directory (default: cwd).
pub fn write_bench_json(path: &str, entries: &[BenchEntry]) -> std::io::Result<()> {
    use crate::util::json::Json;
    let rows: Vec<Json> = entries
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name.as_str())),
                ("value", Json::num(e.value)),
                ("unit", Json::str(e.unit.as_str())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![("schema", Json::str("gd-bench-v1")), ("entries", Json::Arr(rows))]);
    std::fs::write(path, doc.to_string_pretty() + "\n")
}

/// `BENCH_<section>.json` under `GD_BENCH_DIR` (default ".").
pub fn bench_json_path(section: &str) -> String {
    let dir = std::env::var("GD_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    format!("{dir}/BENCH_{section}.json")
}

/// Format tokens/sec the way the paper does ("129k").
pub fn fmt_tps(tps: f64) -> String {
    if tps >= 1e6 {
        format!("{:.2}M", tps / 1e6)
    } else if tps >= 1e3 {
        format!("{:.0}k", tps / 1e3)
    } else {
        format!("{tps:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_tps(129_000.0), "129k");
        assert_eq!(fmt_tps(1_500_000.0), "1.50M");
        assert_eq!(fmt_tps(420.0), "420");
    }

    #[test]
    fn report_tps_speedup_returns_the_ratio() {
        let s = report_tps_speedup("demo", 1000, "seq", 2.0, "batched", 0.5);
        assert!((s - 4.0).abs() < 1e-9);
        // degenerate timings stay finite
        assert!(report_tps_speedup("demo0", 10, "a", 0.0, "b", 0.0).is_finite());
    }

    #[test]
    fn bench_json_round_trips() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("gd_benchjson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_demo.json");
        let entries = [
            BenchEntry::new("dispatch_rows", 128.0, "rows"),
            BenchEntry::new("pack_median", 1250.5, "ns"),
        ];
        write_bench_json(path.to_str().unwrap(), &entries).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("gd-bench-v1"));
        let rows = j.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").and_then(Json::as_str), Some("dispatch_rows"));
        assert_eq!(rows[1].get("value").and_then(Json::as_f64), Some(1250.5));
        assert_eq!(rows[1].get("unit").and_then(Json::as_str), Some("ns"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["Method", "Throughput"]);
        t.row(&["Baseline".into(), "129k".into()]);
        t.print(); // smoke: no panic
    }
}
