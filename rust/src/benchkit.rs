//! Tiny benchmark harness (offline crate set has no criterion).
//!
//! `cargo bench` binaries use `harness = false` and drive this: warmup,
//! N timed iterations, median / p10 / p90 reporting, and table-style
//! output helpers so every paper table/figure bench prints rows in the
//! paper's own format.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Stats { median_ns: q(0.5), p10_ns: q(0.1), p90_ns: q(0.9), iters }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<44} median {:>10}   p10 {:>10}   p90 {:>10}   ({} iters)",
        fmt_ns(s.median_ns),
        fmt_ns(s.p10_ns),
        fmt_ns(s.p90_ns),
        s.iters
    );
}

/// Simple aligned table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// One old-vs-new throughput comparison over a shared work count: prints
/// both variants as tokens/sec plus the speedup, in the same shape as
/// the microbench `[seed]`/`[flat]` rows. The serving bench
/// (`repro bench-serve`) reports batched-vs-sequential decode through
/// this; returns the speedup so smoke gates can assert on it.
pub fn report_tps_speedup(
    name: &str,
    work_tokens: u64,
    base_label: &str,
    base_secs: f64,
    new_label: &str,
    new_secs: f64,
) -> f64 {
    let tps = |secs: f64| work_tokens as f64 / secs.max(1e-12);
    let speedup = base_secs / new_secs.max(1e-12);
    println!(
        "{name:<44} [{base_label}] {:>10}  ({})",
        fmt_tps(tps(base_secs)),
        fmt_ns(base_secs * 1e9),
    );
    println!(
        "{name:<44} [{new_label}] {:>10}  ({})",
        fmt_tps(tps(new_secs)),
        fmt_ns(new_secs * 1e9),
    );
    println!("{name:<44} speedup {speedup:.2}x");
    speedup
}

/// Format tokens/sec the way the paper does ("129k").
pub fn fmt_tps(tps: f64) -> String {
    if tps >= 1e6 {
        format!("{:.2}M", tps / 1e6)
    } else if tps >= 1e3 {
        format!("{:.0}k", tps / 1e3)
    } else {
        format!("{tps:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_tps(129_000.0), "129k");
        assert_eq!(fmt_tps(1_500_000.0), "1.50M");
        assert_eq!(fmt_tps(420.0), "420");
    }

    #[test]
    fn report_tps_speedup_returns_the_ratio() {
        let s = report_tps_speedup("demo", 1000, "seq", 2.0, "batched", 0.5);
        assert!((s - 4.0).abs() < 1e-9);
        // degenerate timings stay finite
        assert!(report_tps_speedup("demo0", 10, "a", 0.0, "b", 0.0).is_finite());
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["Method", "Throughput"]);
        t.row(&["Baseline".into(), "129k".into()]);
        t.print(); // smoke: no panic
    }
}
