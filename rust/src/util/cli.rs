//! Tiny CLI flag parser (offline crate set has no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Each harness declares its flags up front so `--help` output
//! is accurate.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.bools.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
            || self.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn kv_forms() {
        let a = parse("--steps 100 --preset=tiny run --verbose");
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.positional, vec!["run"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("steps", 7), 7);
        assert_eq!(a.f64("p", 0.3), 0.3);
        assert_eq!(a.get_or("mode", "x"), "x");
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--delta=-1.5");
        assert_eq!(a.f64("delta", 0.0), -1.5);
    }
}
