//! Minimal property-testing harness (offline crate set has no proptest).
//!
//! `run_prop` drives a closure over N randomly generated cases from a
//! seeded [`Rng`]; on failure it reports the case index and seed so the
//! case replays deterministically. Generators are plain functions over
//! `&mut Rng` -- composition is ordinary Rust.

use super::rng::Rng;

/// Run `cases` random cases of property `f`. `f` returns Err(msg) on
/// violation. Panics with the seed + case index for replay.
pub fn run_prop<F>(name: &str, cases: usize, seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Generate a vector of length in [min_len, max_len] via `g`.
pub fn vec_of<T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut g: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
    (0..len).map(|_| g(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop("sum-commutes", 50, 1, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a}+{b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure() {
        run_prop("always-fails", 10, 2, |_| Err("nope".into()));
    }

    #[test]
    fn vec_of_respects_bounds() {
        run_prop("vec-bounds", 100, 3, |rng| {
            let v = vec_of(rng, 2, 9, |r| r.below(10));
            if (2..=9).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("len={}", v.len()))
            }
        });
    }
}
