//! Minimal JSON parser/serializer (the offline crate set has no serde).
//!
//! Covers the full JSON grammar we produce and consume: the AOT manifests
//! written by `python/compile/aot.py`, run configs under `configs/`, and
//! the run-record files the harnesses emit. Numbers are parsed as f64;
//! integer accessors check integrality.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panic-free deep access: `j.path(&["config", "n_experts"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- construction / output -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    let _ = write!(out, "\"{k}\": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.peek().ok_or_else(|| self.err("eof in escape"))? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                _ => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"config": {"n": 8, "name": "x", "f": 1.5}}"#).unwrap();
        assert_eq!(v.path(&["config", "n"]).unwrap().as_usize(), Some(8));
        assert_eq!(v.path(&["config", "name"]).unwrap().as_str(), Some("x"));
        assert_eq!(v.path(&["config", "f"]).unwrap().as_f64(), Some(1.5));
        assert_eq!(v.path(&["config", "f"]).unwrap().as_i64(), None);
        assert!(v.path(&["missing"]).is_none());
    }

    #[test]
    fn arrays() {
        let v = Json::parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].as_arr().unwrap()[1].as_i64(), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the aot.py manifest
        let src = r#"{"artifacts": {"train_step": {"file": "t.hlo.txt", "n_params": 65}},
                      "params": [{"name": "embed", "shape": [512, 64], "dtype": "f32"}]}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        let dims = p.get("shape").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = dims.iter().map(|s| s.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![512, 64]);
    }
}
