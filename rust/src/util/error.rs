//! Minimal error-context type (the offline/zero-dependency crate set has
//! no `anyhow`; the reference backend must build with std alone).
//!
//! [`Error`] is a human-readable context chain: `?` converts any
//! `std::error::Error` into it (same blanket-`From` trick anyhow uses --
//! legal because `Error` itself does NOT implement `std::error::Error`),
//! and the [`Context`] extension trait layers "while doing X" messages on
//! `Result` and `Option` exactly like anyhow's. The `err!` / `bail!` /
//! `ensure!` macros live at the crate root (`#[macro_export]`).

use std::fmt;

/// An error as a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// Crate-wide result type (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The root-cause message (last element of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// `Debug` renders the same context chain as `Display` so that
// `fn main() -> Result<()>` prints readable errors on exit.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// The anyhow blanket: any real error type converts by capturing its
// Display rendering. (Allowed because `Error` is not itself a
// `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { chain: vec![e.to_string()] }
    }
}

/// Attach context to errors (drop-in for `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format args (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Return early with an [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

/// Bail unless `cond` holds (drop-in for `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/gd-error-test")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        let msg = e.to_string();
        assert!(msg.starts_with("reading config: "), "got: {msg}");
    }

    #[test]
    fn context_chains_outermost_first() {
        let root: Result<()> = Err(Error::msg("root"));
        let e = root.context("mid").context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = crate::err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                crate::bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
    }

    #[test]
    fn debug_matches_display() {
        let e = Error::msg("a").wrap("b");
        assert_eq!(format!("{e:?}"), format!("{e}"));
    }
}
