//! Substrate utilities: deterministic RNG, JSON, CLI parsing, property
//! testing, and a small bench harness -- all std-only (the offline crate
//! set carries no rand/serde/clap/criterion/proptest).

pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
