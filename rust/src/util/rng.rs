//! Deterministic RNG used everywhere in the coordinator and simulators.
//!
//! The offline crate set has no `rand`, so we carry a small, well-known
//! generator: SplitMix64 (Steele et al. 2014) for seeding / streams plus
//! helpers (uniform, Bernoulli, normal, shuffle). Determinism matters more
//! than statistical perfection here: the gating-dropout decision stream
//! must be reproducible across runs and identical across simulated ranks.

/// SplitMix64: tiny, fast, full 64-bit state, passes BigCrush when used
/// as a stream generator. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (e.g. per-rank, per-purpose).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xBF58476D1CE4E5B9));
        r.next_u64(); // decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Bernoulli(p). This is the paper's per-iteration dropout decision.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's unbiased bounded sampling.
        if n == 0 {
            return 0;
        }
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    ///
    /// Panics on an empty vector, a negative/non-finite weight, or an
    /// all-zero total: every one of those used to fall through to
    /// "return the last index", which silently biased any caller that
    /// built its weights from live counters (the soak harness's
    /// phase-mix sampler does exactly that).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        assert!(!weights.is_empty(), "weighted: empty weight vector");
        let mut total = 0.0f64;
        for (i, w) in weights.iter().enumerate() {
            assert!(w.is_finite() && *w >= 0.0, "weighted: bad weight {w} at index {i}");
            total += w;
        }
        assert!(total > 0.0, "weighted: weights sum to zero");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        // float round-off can leave a sliver of `u` past the last
        // positive weight; land on it rather than on a zero-weight tail
        weights.iter().rposition(|&w| w > 0.0).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_independent() {
        let root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.uniform_in(0.99, 1.01);
            assert!((0.99..1.01).contains(&x));
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2);
    }

    #[test]
    fn weighted_handles_a_zero_weight_tail() {
        // round-off must never land on a zero-weight index, even when it
        // sits last (the old code's silent fallthrough target)
        let mut r = Rng::new(13);
        let w = [2.0, 5.0, 0.0];
        for _ in 0..10_000 {
            assert_ne!(r.weighted(&w), 2);
        }
    }

    #[test]
    #[should_panic(expected = "empty weight vector")]
    fn weighted_rejects_empty() {
        Rng::new(1).weighted(&[]);
    }

    #[test]
    #[should_panic(expected = "weights sum to zero")]
    fn weighted_rejects_all_zero() {
        Rng::new(1).weighted(&[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "bad weight")]
    fn weighted_rejects_negative() {
        Rng::new(1).weighted(&[1.0, -0.5]);
    }
}
