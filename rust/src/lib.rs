//! # gating-dropout
//!
//! A production-shaped reproduction of *Gating Dropout:
//! Communication-efficient Regularization for Sparsely Activated
//! Transformers* (Liu, Kim, Muzio, Awadalla -- ICML 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): the MoE sub-layer hot-spot as
//!   Pallas kernels (gate softmax, one-hot-matmul dispatch/combine, expert
//!   FFN), validated against a pure-jnp oracle.
//! * **Layer 2** (`python/compile/model.py`): the paper's MoE
//!   encoder-decoder transformer with fused fwd+bwd+Adam `train_step`,
//!   AOT-lowered to HLO text.
//! * **Layer 3** (this crate): the paper's system contribution -- the
//!   consensual Gating Dropout [`coordinator`] -- plus every substrate it
//!   needs: the collective [`collective::ThreadFabric`], expert
//!   [`topology`], the pluggable compute [`runtime`], the synthetic
//!   multilingual [`data`] corpus, [`metrics`] (corpus BLEU, throughput),
//!   the [`netmodel`] cluster cost model, the [`simengine`] scaling
//!   sweeps, the single-process [`train`] loop, the real-data-movement
//!   [`distributed`] engine, and the micro-batching [`serve`] subsystem
//!   (batched greedy decode behind `Backend::decode_batch`).
//!
//! The compute [`runtime`] is pluggable (see README "Compute backends"):
//! the default `backend-xla` feature executes the AOT artifacts on PJRT
//! (Python never runs on the training path: `make artifacts` lowers the
//! model once), `backend-ref` is a deterministic pure-Rust reference
//! engine with zero non-std dependencies -- the configuration CI's
//! tier-1 gate builds and tests on a stock toolchain -- and `backend-par`
//! runs that same engine on a deterministic persistent-worker std-thread
//! pool (`runtime::tensor::ThreadPool`, also the per-rank thread budget
//! of the [`distributed`] engine's stage math), bit-identical to
//! `backend-ref` at any thread count.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for measured
//! reproductions of every table and figure in the paper.

// The MoE wire format and the reference tensor kernels are index-heavy
// numeric code; these pedantic lints fight that idiom without making it
// any safer, so they are opted out crate-wide.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod benchkit;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod distributed;
pub mod metrics;
pub mod moe;
pub mod netmodel;
pub mod runtime;
pub mod serve;
pub mod simengine;
pub mod topology;
pub mod train;
pub mod util;
